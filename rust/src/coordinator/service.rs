//! Drift-aware recalibration **server**: the concurrent runtime loop
//! that closes the paper's §III-A persistence story.
//!
//! The paper stores identified calibration bit patterns in non-volatile
//! memory "so it can be reused across different environments and system
//! reboots" — but reuse is only safe while conditions hold, and a
//! serving deployment cannot stop the world to re-check them. This
//! module therefore treats each subarray's calibration as a **cached
//! artifact with drift-driven invalidation**, maintained *while the
//! device keeps serving*:
//!
//! serve → admit → shard → worker → drain
//!
//! 1. **serve** — any number of client threads call
//!    [`RecalibService::serve_workload`] / [`RecalibService::serve_plan`]
//!    (arithmetic) or [`RecalibService::serve`] (measurement batteries)
//!    concurrently; every method takes `&self`;
//! 2. **admit** — the serve path passes admission control first:
//!    at most `ServiceConfig::max_inflight_serves` requests run at
//!    once, the rest are rejected immediately with the typed
//!    [`PudError::Overloaded`] (bounded backpressure — the caller
//!    retries, nothing queues unboundedly), and a draining service
//!    rejects with [`PudError::Draining`];
//! 3. **shard** — entries live in per-channel shards, each behind its
//!    own lock: banks on different channels never contend, and no lock
//!    is ever held across an engine call, so background recalibration
//!    of channel 0 cannot stall serving on channel 1 (nor can a
//!    panicking engine poison the map — see `worker`);
//! 4. **worker** — a [`ServiceServer`] owns background threads: N
//!    recalibration workers drain the drift queue (claim → engine →
//!    write-back, panic-contained per job) and one maintenance ticker
//!    runs [`RecalibService::maintain`] (drift polls + scrub cadence)
//!    every `ServiceConfig::maintain_every_ms`;
//! 5. **drain** — [`ServiceServer::drain`] stops admission, lets
//!    in-flight serves and every queued recalibration finish, joins
//!    all threads and returns the persisted [`CalibStore`] snapshot
//!    ([`ServiceServer::shutdown`] is the fast variant that abandons
//!    still-queued jobs; both record `drain.*` metrics).
//!
//! The synchronous entry points ([`RecalibService::run_pending`],
//! [`RecalibService::poll_drift`], ...) remain: a `ServiceServer` is
//! how production serves, but experiments and tests may still drive
//! the lifecycle step by step on one thread.
//!
//! ## Lifecycle
//!
//! * **rehydrate** — [`RecalibService::load_store`] decodes every
//!   registered subarray's stored entry, then either fast-accepts it
//!   when its stored identification environment matches the live one
//!   within [`DriftPolicy::env_matches`] tolerance
//!   ([`LoadOutcome::AcceptedOnEnv`] — no measurement spent) or runs
//!   one *batched* cheap ECR spot check and accepts/rejects against
//!   [`DriftPolicy::accept_max_ecr`];
//! * **monitor** — [`RecalibService::poll_drift`] evaluates drift
//!   signals (temperature excursion, retention age, rolling
//!   served-batch ECR) and queues background recalibration;
//! * **recalibrate** — worker threads (or `run_pending`) drain the
//!   queue through [`crate::calib::engine::calibrate_isolated`]:
//!   exactly-once per queued signal (a claimed entry is marked
//!   `running`, so concurrent polls cannot double-schedule it), a
//!   panicking bank degrades to one error slot, successes re-anchor
//!   their monitor; [`RecalibService::snapshot_store`] re-persists.
//!
//! Serving and recalibration are decoupled: a stale bank keeps serving
//! its last-good levels and mask until background recalibration lands.
//!
//! ## Fault countermeasures
//!
//! Calibration cancels *smooth* error sources; PuDGhost-style faults
//! ([`crate::dram::faults`]) only surface as golden mismatches on
//! served workloads. Three opt-in countermeasures close that gap:
//! **quarantine with hysteresis** ([`Quarantine`]), **redundant
//! execution** (`ServiceConfig::redundancy`), and **scrub passes**
//! (`ServiceConfig::scrub_every`, [`RecalibService::scrub`] — replays
//! the last served workload unmasked, so detection sees exactly the
//! corruption serving sees). Costs and effects are reported via the
//! `fault.*` / `quarantine.*` / `scrub.*` metrics and pinned by
//! `rust/tests/fault_campaign.rs`; the threaded lifecycle itself is
//! pinned by `rust/tests/concurrent_service.rs` under ThreadSanitizer.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::analysis::ecr::EcrReport;
use crate::calib::algorithm::{CalibParams, Calibration, SPOT_CHECK_SAMPLES};
use crate::calib::drift::{DriftMonitor, DriftPolicy, DriftSignal};
use crate::calib::engine::{
    calibrate_isolated, execute_isolated, measure_ecr_isolated, CalibEngine, CalibRequest,
    ComputeEngine, ComputeRequest, ComputeResult, EcrRequest,
};
use crate::calib::lattice::FracConfig;
use crate::calib::store::CalibStore;
use crate::config::device::DeviceConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::worker;
use crate::dram::geometry::SubarrayId;
use crate::dram::subarray::Subarray;
use crate::pud::plan::{PudError, PudOp, WorkloadPlan};
use crate::pud::ranges::{OperandRange, RangeClass};
use crate::util::rng::derive_seed;

/// Stream-domain tag of served workload batteries (each serve call
/// draws fresh patterns from its epoch).
const SERVE_STREAM: u64 = 0x5E12F;
/// Stream-domain tag of the load-time acceptance spot check.
const SPOT_CHECK_STREAM: u64 = 0x57CC;

/// Lock a mutex, recovering the guard even if a previous holder
/// panicked: every critical section here is short, pure bookkeeping
/// (engine calls run outside all locks), so continuing past a poison
/// marker is always sound — and it is what keeps the sharded map
/// usable after an injected worker panic.
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Service-level configuration: what to calibrate for, how to judge
/// drift, and how the threaded server behaves.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Frac configuration served and recalibrated (paper: T_{2,1,0}).
    pub config: FracConfig,
    /// Algorithm-1 parameters for (re)calibration.
    pub params: CalibParams,
    /// Drift thresholds.
    pub policy: DriftPolicy,
    /// Operand count of served MAJX workloads.
    pub serve_m: usize,
    /// Battery depth of one served workload batch.
    pub serve_samples: u32,
    /// Battery depth of the load-time acceptance spot check.
    pub spot_check_samples: u32,
    /// Golden mismatches before a column is quarantined out of the
    /// arithmetic mask (`0` disables quarantine — the default).
    pub quarantine_strikes: usize,
    /// Consecutive clean scrub passes before a quarantined column
    /// re-enters the mask (hysteresis; ignored while quarantine is
    /// disabled).
    pub quarantine_clean_passes: usize,
    /// Redundant-execution factor for served workloads (`1` = single
    /// run, the default; `N > 1` majority-votes N replica runs).
    pub redundancy: usize,
    /// Run a scrub pass every N maintenance polls (`0` disables scrub
    /// — the default). See [`RecalibService::scrub`].
    pub scrub_every: usize,
    /// Admission bound: maximum concurrently admitted
    /// `serve_plan`/`serve_workload` calls; further calls are rejected
    /// with [`PudError::Overloaded`] (`0` = unbounded).
    pub max_inflight_serves: usize,
    /// [`ServiceServer`] maintenance-ticker interval, milliseconds
    /// (drift polls + scrub cadence).
    pub maintain_every_ms: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            config: FracConfig::pudtune([2, 1, 0]),
            params: CalibParams::paper(),
            policy: DriftPolicy::default(),
            serve_m: 5,
            serve_samples: 2048,
            spot_check_samples: SPOT_CHECK_SAMPLES,
            quarantine_strikes: 0,
            quarantine_clean_passes: 2,
            redundancy: 1,
            scrub_every: 0,
            max_inflight_serves: 256,
            maintain_every_ms: 25,
        }
    }
}

/// Per-column quarantine state with hysteresis: a column is expelled
/// from the arithmetic mask after `strikes_to_enter` observed golden
/// mismatches (served batches and scrub passes both strike) and
/// readmitted only after `clean_to_release` *consecutive* clean scrub
/// passes — a dirty scrub resets the clean counter, so duty-cycled
/// intermittent columns cannot flap back into service.
/// `strikes_to_enter == 0` disables the whole mechanism.
#[derive(Clone, Debug)]
pub struct Quarantine {
    strikes_to_enter: usize,
    clean_to_release: usize,
    /// Cumulative mismatch strikes per column (not reset by clean
    /// serves: intermittent faults must not launder their history).
    strikes: Vec<u32>,
    /// Columns currently quarantined out of the mask.
    out: Vec<bool>,
    /// Consecutive clean scrub passes per quarantined column.
    clean: Vec<u32>,
}

/// One quarantine update's bookkeeping (fed into the `quarantine.*` /
/// `scrub.*` metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QuarantineDelta {
    /// Columns newly quarantined by this observation.
    pub entered: usize,
    /// Quarantined columns released back into the mask.
    pub released: usize,
    /// Columns observed mismatching in this observation.
    pub dirty: usize,
}

impl Quarantine {
    pub fn new(cols: usize, strikes_to_enter: usize, clean_to_release: usize) -> Self {
        Self {
            strikes_to_enter,
            clean_to_release: clean_to_release.max(1),
            strikes: vec![0; cols],
            out: vec![false; cols],
            clean: vec![0; cols],
        }
    }

    /// Whether the mechanism is active at all.
    pub fn enabled(&self) -> bool {
        self.strikes_to_enter > 0
    }

    /// Columns currently quarantined.
    pub fn quarantined_cols(&self) -> usize {
        self.out.iter().filter(|&&q| q).count()
    }

    /// Whether column `c` is currently quarantined.
    pub fn is_quarantined(&self, c: usize) -> bool {
        self.out.get(c).copied().unwrap_or(false)
    }

    /// Remove quarantined columns from an arithmetic mask.
    pub fn apply(&self, mask: &mut [bool]) {
        if !self.enabled() {
            return;
        }
        for (m, &q) in mask.iter_mut().zip(&self.out) {
            if q {
                *m = false;
            }
        }
    }

    /// Record one served batch's per-column golden mismatches
    /// (`bad[c]` = column `c` was served and mismatched). Serving only
    /// strikes toward entry; release requires scrub evidence.
    pub fn observe_serve(&mut self, bad: &[bool]) -> QuarantineDelta {
        let mut delta = QuarantineDelta::default();
        if !self.enabled() {
            return delta;
        }
        for (c, &b) in bad.iter().enumerate() {
            if !b {
                continue;
            }
            delta.dirty += 1;
            if !self.out[c] {
                self.strikes[c] += 1;
                if self.strikes[c] as usize >= self.strikes_to_enter {
                    self.out[c] = true;
                    self.clean[c] = 0;
                    delta.entered += 1;
                }
            }
        }
        delta
    }

    /// Record one *unmasked* scrub pass: dirty columns strike toward
    /// (or stay in) quarantine, clean quarantined columns count toward
    /// hysteresis release.
    pub fn observe_scrub(&mut self, bad: &[bool]) -> QuarantineDelta {
        let mut delta = QuarantineDelta::default();
        if !self.enabled() {
            return delta;
        }
        for (c, &b) in bad.iter().enumerate() {
            if self.out[c] {
                if b {
                    delta.dirty += 1;
                    self.clean[c] = 0;
                } else {
                    self.clean[c] += 1;
                    if self.clean[c] as usize >= self.clean_to_release {
                        self.out[c] = false;
                        self.strikes[c] = 0;
                        self.clean[c] = 0;
                        delta.released += 1;
                    }
                }
            } else if b {
                delta.dirty += 1;
                self.strikes[c] += 1;
                if self.strikes[c] as usize >= self.strikes_to_enter {
                    self.out[c] = true;
                    self.clean[c] = 0;
                    delta.entered += 1;
                }
            }
        }
        delta
    }
}

/// Where a subarray's active calibration currently stands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntryState {
    /// Spot-checked (or freshly identified) and trusted.
    Accepted,
    /// Drift detected; still serving the old levels until background
    /// recalibration replaces them.
    Stale,
    /// No trusted calibration yet (missing/rejected store entry or
    /// failed recalibration): serving the uniform neutral levels.
    Uncalibrated,
}

/// Result of rehydrating one subarray from the store.
#[derive(Clone, Debug)]
pub enum LoadOutcome {
    /// Entry decoded and passed the spot check.
    Accepted { spot_ecr: f64 },
    /// Entry decoded and its stored identification environment matched
    /// the live one within [`DriftPolicy::env_matches`] tolerance: the
    /// ECR spot check was skipped entirely (opt-in fast path; deltas
    /// are |stored − live| on each axis).
    AcceptedOnEnv { temp_delta_c: f64, hours_delta: f64 },
    /// Entry decoded but its spot-check ECR exceeded the policy bound.
    Rejected { spot_ecr: f64 },
    /// The store has no entry for this subarray.
    Missing,
    /// The entry exists but is unusable (geometry mismatch, corrupt
    /// levels, or a failed spot-check measurement).
    Incompatible(String),
}

/// One subarray's result from a served workload batch.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    pub id: SubarrayId,
    /// Entry state at serve time (stale entries still serve).
    pub state: EntryState,
    /// The measured battery, or the per-bank failure that degraded it.
    pub report: Result<EcrReport, String>,
}

/// One subarray's result from a served arithmetic workload batch
/// ([`RecalibService::serve_workload`]).
#[derive(Clone, Debug)]
pub struct WorkloadOutcome {
    pub id: SubarrayId,
    /// Entry state at serve time (stale entries still serve).
    pub state: EntryState,
    /// The executed batch, or the per-bank failure that degraded it.
    pub result: Result<ComputeResult, String>,
    /// Masked (error-free) columns whose outputs matched the software
    /// golden model.
    pub golden_correct: usize,
    /// Masked columns the workload was served on.
    pub active_cols: usize,
}

/// One subarray's result from a scrub pass ([`RecalibService::scrub`]).
#[derive(Clone, Debug)]
pub struct ScrubOutcome {
    pub id: SubarrayId,
    /// The replayed batch's per-bank failure, if any (a failed replay
    /// changes no quarantine state).
    pub result: Result<(), String>,
    /// Quarantine transitions this pass caused on the subarray.
    pub delta: QuarantineDelta,
}

struct Entry {
    sub: Subarray,
    seed: u64,
    calib: Calibration,
    state: EntryState,
    monitor: DriftMonitor,
    /// Whether the entry currently sits in the recalibration queue.
    queued: bool,
    /// Whether a recalibration job for this entry is executing right
    /// now (claimed off the queue, engine call in flight). Guards the
    /// window between claim and write-back: `poll_drift` must not
    /// re-queue an entry whose repair is already running, or one drift
    /// signal would recalibrate twice.
    running: bool,
    /// Arithmetic-usable column mask (MAJ5 ∧ MAJ3 error-free) from the
    /// most recent battery measured under the *current* calibration
    /// (spot check or served batch); `None` until one lands, and
    /// cleared when recalibration swaps the levels.
    mask: Option<Vec<bool>>,
    /// Per-column fault quarantine (disabled unless the service config
    /// sets `quarantine_strikes`). Survives recalibration: faults are
    /// a property of the column, not of the levels.
    quarantine: Quarantine,
}

/// One channel's entries behind their own lock: banks on different
/// channels never contend, and recalibration write-backs on one
/// channel cannot stall serve-path reads on another.
struct ChannelShard {
    channel: usize,
    entries: Mutex<BTreeMap<SubarrayId, Entry>>,
}

/// Cross-thread scheduler state: the recalibration queue plus the
/// admission/lifecycle flags, all behind one short-critical-section
/// mutex (engine work never runs under it).
struct Scheduler {
    /// FIFO of subarrays awaiting background recalibration. An id
    /// appears at most once (guarded by `Entry::queued`).
    queue: VecDeque<SubarrayId>,
    /// Cleared when drain/shutdown begins: the serve path stops
    /// admitting and the maintenance ticker stops scheduling.
    accepting: bool,
    /// Set when workers must exit (after quiescence on drain).
    stop: bool,
    /// Recalibration jobs claimed off the queue and executing now.
    active_jobs: usize,
    /// Serve-path requests past admission and not yet finished.
    inflight_serves: usize,
}

/// The drift-aware recalibration service (module docs for the loop).
///
/// Every method takes `&self`: state lives in per-channel shards and a
/// scheduler mutex, so any number of threads may serve, poll and
/// recalibrate concurrently — wrap one in an [`Arc`] and hand it to a
/// [`ServiceServer`] for the background loop.
pub struct RecalibService<E> {
    pub cfg: DeviceConfig,
    svc: ServiceConfig,
    engine: E,
    threads: usize,
    /// Per-channel shards, sorted by channel id (registration creates
    /// them on demand; the outer lock is only written on registration).
    shards: RwLock<Vec<Arc<ChannelShard>>>,
    sched: Mutex<Scheduler>,
    /// Wakes recalibration workers when jobs arrive or `stop` flips.
    job_cv: Condvar,
    /// Wakes the maintenance ticker early on drain.
    tick_cv: Condvar,
    /// Wakes drain when in-flight serves / active jobs finish.
    idle_cv: Condvar,
    /// Bumped per serve call: every batch draws fresh patterns.
    serve_epoch: AtomicU64,
    /// Maintenance polls so far (drives the scrub cadence).
    polls: AtomicU64,
    /// Set when the scrub cadence fires; cleared by [`Self::scrub`].
    scrub_pending: AtomicBool,
    /// The last served workload — what a scrub pass replays unmasked,
    /// so scrub detection sees exactly the corruption serving sees.
    last_workload: Mutex<Option<(Arc<WorkloadPlan>, Arc<Vec<Vec<u64>>>)>>,
    pub metrics: Arc<Metrics>,
}

impl<E: CalibEngine + Sync> RecalibService<E> {
    pub fn new(cfg: DeviceConfig, svc: ServiceConfig, engine: E) -> Result<Self, String> {
        cfg.validate()?;
        svc.policy.validate()?;
        Ok(Self {
            cfg,
            svc,
            engine,
            threads: worker::default_threads(),
            shards: RwLock::new(Vec::new()),
            sched: Mutex::new(Scheduler {
                queue: VecDeque::new(),
                accepting: true,
                stop: false,
                active_jobs: 0,
                inflight_serves: 0,
            }),
            job_cv: Condvar::new(),
            tick_cv: Condvar::new(),
            idle_cv: Condvar::new(),
            serve_epoch: AtomicU64::new(0),
            polls: AtomicU64::new(0),
            scrub_pending: AtomicBool::new(false),
            last_workload: Mutex::new(None),
            metrics: Arc::new(Metrics::new()),
        })
    }

    /// Shards sorted by channel: iterating them (each shard's BTreeMap
    /// in order) yields globally id-ordered traversal, since `channel`
    /// is [`SubarrayId`]'s leading `Ord` field.
    fn shards_snapshot(&self) -> Vec<Arc<ChannelShard>> {
        self.shards
            .read()
            .unwrap_or_else(|p| p.into_inner())
            .clone()
    }

    fn shard_of(&self, channel: usize) -> Option<Arc<ChannelShard>> {
        let shards = self.shards.read().unwrap_or_else(|p| p.into_inner());
        shards
            .binary_search_by_key(&channel, |s| s.channel)
            .ok()
            .map(|i| shards[i].clone())
    }

    /// Run `f` on one entry under its shard lock (short sections only
    /// — never call the engine from inside).
    fn with_entry<R>(&self, id: SubarrayId, f: impl FnOnce(&mut Entry) -> R) -> Option<R> {
        let shard = self.shard_of(id.channel)?;
        let mut entries = lock_clean(&shard.entries);
        entries.get_mut(&id).map(f)
    }

    /// Register one subarray, manufactured from the device seed along
    /// its address path (the same derivation the experiment paths
    /// use). Starts `Uncalibrated` (serving neutral levels) and queued
    /// for calibration; [`Self::load_store`] may satisfy it first.
    pub fn register(&self, id: SubarrayId, rows: usize, cols: usize, device_seed: u64) {
        let seed = derive_seed(device_seed, &id.seed_path());
        let sub = Subarray::with_geometry(&self.cfg, rows, cols, seed);
        let calib = self.svc.config.uncalibrated(&self.cfg, cols);
        let monitor = DriftMonitor::new(&sub.env, self.svc.policy.serve_window);
        let quarantine = Quarantine::new(
            cols,
            self.svc.quarantine_strikes,
            self.svc.quarantine_clean_passes,
        );
        let entry = Entry {
            sub,
            seed,
            calib,
            state: EntryState::Uncalibrated,
            monitor,
            queued: false,
            running: false,
            mask: None,
            quarantine,
        };
        let shard = {
            let mut shards = self.shards.write().unwrap_or_else(|p| p.into_inner());
            match shards.binary_search_by_key(&id.channel, |s| s.channel) {
                Ok(i) => shards[i].clone(),
                Err(i) => {
                    let shard = Arc::new(ChannelShard {
                        channel: id.channel,
                        entries: Mutex::new(BTreeMap::new()),
                    });
                    shards.insert(i, shard.clone());
                    shard
                }
            }
        };
        lock_clean(&shard.entries).insert(id, entry);
        self.enqueue(id);
    }

    /// Mark `id` queued (under its shard lock) and push it onto the
    /// scheduler queue. The queued-flag transition guarantees an id
    /// appears in the queue at most once.
    fn enqueue(&self, id: SubarrayId) {
        let newly_queued = self
            .with_entry(id, |e| {
                if e.queued {
                    false
                } else {
                    e.queued = true;
                    true
                }
            })
            .unwrap_or(false);
        if newly_queued {
            lock_clean(&self.sched).queue.push_back(id);
            self.job_cv.notify_all();
        }
    }

    /// Force one subarray onto the recalibration queue (operator API /
    /// bench driver): an `Accepted` entry goes `Stale` and background
    /// workers repair it. Returns false for unknown ids.
    pub fn request_recalibration(&self, id: SubarrayId) -> bool {
        let known = self
            .with_entry(id, |e| {
                if e.state == EntryState::Accepted {
                    e.state = EntryState::Stale;
                }
            })
            .is_some();
        if known {
            self.metrics.incr("recalib.requested");
            self.enqueue(id);
        }
        known
    }

    pub fn len(&self) -> usize {
        self.shards_snapshot()
            .iter()
            .map(|s| lock_clean(&s.entries).len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn ids(&self) -> Vec<SubarrayId> {
        let mut out = Vec::new();
        for shard in self.shards_snapshot() {
            out.extend(lock_clean(&shard.entries).keys().copied());
        }
        out
    }

    pub fn state(&self, id: SubarrayId) -> Option<EntryState> {
        self.with_entry(id, |e| e.state)
    }

    /// The calibration currently serving for `id` (a clone: entries
    /// live behind shard locks, so references cannot escape).
    pub fn calibration(&self, id: SubarrayId) -> Option<Calibration> {
        self.with_entry(id, |e| e.calib.clone())
    }

    /// Subarrays awaiting background recalibration.
    pub fn pending(&self) -> usize {
        self.shards_snapshot()
            .iter()
            .map(|s| lock_clean(&s.entries).values().filter(|e| e.queued).count())
            .sum()
    }

    /// One subarray's quarantine state (`None` for unknown ids).
    pub fn quarantine(&self, id: SubarrayId) -> Option<Quarantine> {
        self.with_entry(id, |e| e.quarantine.clone())
    }

    /// Whether the scrub cadence has fired since the last scrub pass.
    pub fn scrub_pending(&self) -> bool {
        self.scrub_pending.load(Ordering::Relaxed)
    }

    /// Whether the service is still admitting serve requests (false
    /// once a drain/shutdown began).
    pub fn is_accepting(&self) -> bool {
        lock_clean(&self.sched).accepting
    }

    /// Rehydrate every registered subarray from a store: checked
    /// decode, then per entry either the environment-match fast accept
    /// (stored v2 env within [`DriftPolicy::env_matches`] tolerance of
    /// the live one — no measurement spent, `recalib.accepted_on_env`)
    /// or ONE batched ECR spot check over all remaining candidates and
    /// per-entry accept/reject. Rejections and incompatibilities count
    /// into `recalib.rejected_on_load` and leave the entry queued for
    /// recalibration.
    pub fn load_store(&self, store: &CalibStore) -> Vec<(SubarrayId, LoadOutcome)> {
        let mut outcomes: Vec<(SubarrayId, LoadOutcome)> = Vec::new();
        let mut candidates: Vec<(SubarrayId, Calibration)> = Vec::new();
        // One batched spot check for every candidate: both MAJ
        // arities, so an accepted entry starts with a trustworthy
        // arithmetic-usable mask, not just a MAJ-`serve_m` one.
        let other_m = 8 - self.svc.serve_m;
        let mut reqs: Vec<EcrRequest> = Vec::new();
        for shard in self.shards_snapshot() {
            let mut entries = lock_clean(&shard.entries);
            for (&id, entry) in entries.iter_mut() {
                match store.load_expecting(id, &self.cfg, entry.sub.cols) {
                    Ok(Some(calib)) => {
                        if let Some(env) = store.stored_env(id) {
                            // v2 env-metadata gate: levels identified at
                            // a die temperature the drift policy would
                            // already have flagged are rejected before
                            // spending a spot check on them. v1 entries
                            // (no env) skip the gate and rely on the
                            // spot check alone.
                            let delta = (env.temp_c - entry.sub.env.temp_c).abs();
                            if delta > self.svc.policy.max_temp_delta_c {
                                self.metrics.incr("recalib.rejected_on_load");
                                outcomes.push((
                                    id,
                                    LoadOutcome::Incompatible(format!(
                                        "stored calibration env is {delta:.1} C from the \
                                         current die temperature (policy allows {:.1} C)",
                                        self.svc.policy.max_temp_delta_c
                                    )),
                                ));
                                continue;
                            }
                            // Environment-match fast accept (opt-in):
                            // the stored env is close enough that the
                            // calibration is trusted as-is — anchored
                            // at its *stored* env, so aging continues
                            // from identification, not from reboot.
                            if let Some((temp_delta_c, hours_delta)) =
                                self.svc.policy.env_matches(&env, &entry.sub.env)
                            {
                                entry.calib = calib;
                                entry.state = EntryState::Accepted;
                                entry.monitor =
                                    DriftMonitor::new(&env, self.svc.policy.serve_window);
                                entry.queued = false; // drop any pending cold-start job
                                entry.mask = None; // first battery establishes it
                                self.metrics.incr("recalib.accepted_on_env");
                                outcomes.push((
                                    id,
                                    LoadOutcome::AcceptedOnEnv { temp_delta_c, hours_delta },
                                ));
                                continue;
                            }
                        }
                        for m in [self.svc.serve_m, other_m] {
                            reqs.push(
                                EcrRequest::from_subarray(
                                    &entry.sub,
                                    entry.seed,
                                    calib.clone(),
                                    m,
                                    self.svc.spot_check_samples,
                                )
                                .with_seed(SPOT_CHECK_STREAM),
                            );
                        }
                        candidates.push((id, calib));
                    }
                    Ok(None) => outcomes.push((id, LoadOutcome::Missing)),
                    Err(e) => {
                        self.metrics.incr("recalib.rejected_on_load");
                        outcomes.push((id, LoadOutcome::Incompatible(e)));
                    }
                }
            }
        }
        // The batched measurement runs with no shard lock held.
        let mut reports = self
            .metrics
            .time("service.spot_check", || {
                measure_ecr_isolated(&self.engine, &reqs, self.threads)
            })
            .into_iter();
        for (id, calib) in candidates {
            let primary = reports.next().expect("one primary spot check per candidate");
            let secondary = reports.next().expect("one secondary spot check per candidate");
            let outcome = match (primary, secondary) {
                (Ok(rep), Ok(sec)) => {
                    let spot_ecr = rep.ecr();
                    if spot_ecr <= self.svc.policy.accept_max_ecr {
                        let window = self.svc.policy.serve_window;
                        let mask = rep.intersect(&sec).error_free_mask();
                        self.with_entry(id, |entry| {
                            entry.calib = calib;
                            entry.state = EntryState::Accepted;
                            entry.monitor = DriftMonitor::new(&entry.sub.env, window);
                            entry.queued = false; // drop any pending cold-start job
                            entry.mask = Some(mask);
                        });
                        self.metrics.incr("recalib.accepted_on_load");
                        LoadOutcome::Accepted { spot_ecr }
                    } else {
                        self.metrics.incr("recalib.rejected_on_load");
                        LoadOutcome::Rejected { spot_ecr }
                    }
                }
                (Err(e), _) | (_, Err(e)) => {
                    self.metrics.incr("recalib.rejected_on_load");
                    LoadOutcome::Incompatible(format!("spot check failed: {e}"))
                }
            };
            outcomes.push((id, outcome));
        }
        outcomes.sort_by_key(|(id, _)| *id);
        outcomes
    }

    /// Serve one workload batch on every subarray (one batched engine
    /// call, per-bank fault isolation): measures `serve_samples`
    /// random patterns at *both* MAJ arities under each entry's
    /// current calibration, feeds the primary (MAJ-`serve_m`) ECR into
    /// the drift monitors, refreshes the entry's arithmetic-usable
    /// mask (MAJ5 ∧ MAJ3 error-free — what [`Self::serve_plan`]
    /// restricts compute to), and never touches the recalibration
    /// queue — a stale entry keeps serving its old levels until
    /// background recalibration lands.
    pub fn serve(&self) -> Vec<ServeOutcome> {
        let epoch = self.serve_epoch.fetch_add(1, Ordering::Relaxed) + 1;
        let seed = derive_seed(SERVE_STREAM, &[epoch]);
        let other_m = 8 - self.svc.serve_m;
        let mut ids = Vec::new();
        let mut reqs = Vec::new();
        for shard in self.shards_snapshot() {
            let entries = lock_clean(&shard.entries);
            for (&id, entry) in entries.iter() {
                ids.push(id);
                for m in [self.svc.serve_m, other_m] {
                    reqs.push(
                        EcrRequest::from_subarray(
                            &entry.sub,
                            entry.seed,
                            entry.calib.clone(),
                            m,
                            self.svc.serve_samples,
                        )
                        .with_seed(seed),
                    );
                }
            }
        }
        let mut reports = self
            .metrics
            .time("service.serve", || {
                measure_ecr_isolated(&self.engine, &reqs, self.threads)
            })
            .into_iter();
        ids.into_iter()
            .map(|id| {
                let primary = reports.next().expect("one primary report per entry");
                let secondary = reports.next().expect("one secondary report per entry");
                let state = self
                    .with_entry(id, |entry| {
                        match (&primary, &secondary) {
                            (Ok(rep), Ok(sec)) => {
                                entry.monitor.observe_ecr(rep.ecr());
                                entry.mask = Some(rep.intersect(sec).error_free_mask());
                                self.metrics.incr("serve.batches");
                            }
                            (Ok(rep), Err(_)) => {
                                // The primary battery still monitors
                                // drift; the mask keeps its last
                                // trusted value.
                                entry.monitor.observe_ecr(rep.ecr());
                                self.metrics.incr("serve.batches");
                                self.metrics.incr("serve.bank_failures");
                            }
                            (Err(_), _) => self.metrics.incr("serve.bank_failures"),
                        }
                        entry.state
                    })
                    .unwrap_or(EntryState::Uncalibrated);
                ServeOutcome { id, state, report: primary }
            })
            .collect()
    }

    /// Evaluate drift for every accepted entry and schedule background
    /// recalibration for the drifted ones (metric `recalib.scheduled`).
    /// Entries whose earlier recalibration failed (stale/uncalibrated,
    /// neither queued nor running) are re-queued here too
    /// (`recalib.rescheduled`), so faults retry on the next
    /// maintenance pass. Returns the fresh drift signals.
    pub fn poll_drift(&self) -> Vec<(SubarrayId, DriftSignal)> {
        let polls = self.polls.fetch_add(1, Ordering::Relaxed) + 1;
        if self.svc.scrub_every > 0 && polls % self.svc.scrub_every as u64 == 0 {
            // Scrubbing needs a compute-capable engine; the poll only
            // raises the flag, [`Self::maintain`] (or an explicit
            // [`Self::scrub`]) runs the pass.
            self.scrub_pending.store(true, Ordering::Relaxed);
        }
        let mut signals = Vec::new();
        let mut to_push = Vec::new();
        for shard in self.shards_snapshot() {
            let mut entries = lock_clean(&shard.entries);
            for (&id, entry) in entries.iter_mut() {
                match entry.state {
                    EntryState::Accepted => {
                        if let Some(sig) = entry.monitor.check(&self.svc.policy, &entry.sub.env)
                        {
                            entry.state = EntryState::Stale;
                            self.metrics.incr("recalib.scheduled");
                            signals.push((id, sig));
                            if !entry.queued {
                                entry.queued = true;
                                to_push.push(id);
                            }
                        }
                    }
                    EntryState::Stale | EntryState::Uncalibrated => {
                        // `running` covers the claim→write-back window:
                        // an entry being repaired right now must not be
                        // queued a second time for the same signal.
                        if !entry.queued && !entry.running {
                            self.metrics.incr("recalib.rescheduled");
                            entry.queued = true;
                            to_push.push(id);
                        }
                    }
                }
            }
        }
        if !to_push.is_empty() {
            lock_clean(&self.sched).queue.extend(to_push);
            self.job_cv.notify_all();
        }
        signals
    }

    /// Claim one popped queue element: skip stale elements (their
    /// entry was satisfied by a later `load_store`) and mark the entry
    /// `running` so polls cannot double-schedule it while the engine
    /// call is in flight.
    fn claim(&self, id: SubarrayId) -> Option<CalibRequest> {
        self.with_entry(id, |entry| {
            if !entry.queued {
                return None;
            }
            entry.queued = false;
            entry.running = true;
            Some(CalibRequest::from_subarray(
                &entry.sub,
                entry.seed,
                self.svc.config,
                self.svc.params,
            ))
        })
        .flatten()
    }

    /// Write one recalibration result back under the shard lock.
    fn finish_job(&self, id: SubarrayId, result: Result<Calibration, String>) -> Result<(), String> {
        self.with_entry(id, |entry| {
            entry.running = false;
            match result {
                Ok(calib) => {
                    entry.calib = calib;
                    entry.state = EntryState::Accepted;
                    entry.monitor.rebase(&entry.sub.env);
                    // The old mask measured the old levels; the next
                    // battery under the new calibration re-establishes
                    // it.
                    entry.mask = None;
                    self.metrics.incr("recalib.completed");
                    Ok(())
                }
                Err(e) => {
                    self.metrics.incr("recalib.failed");
                    Err(e)
                }
            }
        })
        .unwrap_or_else(|| Err("entry disappeared during recalibration".to_string()))
    }

    /// One background worker job: claim, recalibrate (panic-contained
    /// inside `calibrate_isolated`), write back.
    fn run_one_background(&self, id: SubarrayId) {
        let Some(req) = self.claim(id) else {
            return;
        };
        self.metrics.incr("recalib.background");
        let result = self
            .metrics
            .time("service.recalibrate", || {
                calibrate_isolated(&self.engine, &[req], 1)
            })
            .pop()
            .unwrap_or_else(|| Err("engine returned no result".to_string()));
        let _ = self.finish_job(id, result);
    }

    /// Drain up to `max_jobs` queued recalibrations through the engine
    /// (one isolated batch: worker-pool fan-out, a panicking bank
    /// degrades to one error). Successes swap in the new calibration
    /// and re-anchor their drift monitor; failures keep the previous
    /// levels serving and are retried on the next [`Self::poll_drift`].
    /// Synchronous counterpart of the [`ServiceServer`] worker threads
    /// (both claim from the same queue, so they compose).
    pub fn run_pending(&self, max_jobs: usize) -> Vec<(SubarrayId, Result<(), String>)> {
        let mut ids = Vec::new();
        let mut reqs = Vec::new();
        while ids.len() < max_jobs {
            let popped = lock_clean(&self.sched).queue.pop_front();
            let Some(id) = popped else {
                break;
            };
            if let Some(req) = self.claim(id) {
                ids.push(id);
                reqs.push(req);
            }
        }
        if ids.is_empty() {
            return Vec::new();
        }
        let results = self.metrics.time("service.recalibrate", || {
            calibrate_isolated(&self.engine, &reqs, self.threads)
        });
        ids.into_iter()
            .zip(results)
            .map(|(id, result)| (id, self.finish_job(id, result)))
            .collect()
    }

    /// Snapshot the current calibrations into a persistable store —
    /// the write-back half of the lifecycle. Stale entries are
    /// included too: they are the last-known-good identification, and
    /// a shutdown between drift detection and repair should not erase
    /// them (the load-time spot check re-validates every entry on the
    /// next boot anyway). Only `Uncalibrated` entries — serving the
    /// uniform neutral levels — carry nothing worth persisting.
    pub fn snapshot_store(&self) -> CalibStore {
        let mut store = CalibStore::default();
        for shard in self.shards_snapshot() {
            let entries = lock_clean(&shard.entries);
            for (&id, entry) in entries.iter() {
                if entry.state != EntryState::Uncalibrated {
                    // v2 metadata: the environment the levels were
                    // identified/accepted under.
                    store.insert_with_env(id, &entry.calib, entry.monitor.calib_env());
                }
            }
        }
        store
    }

    /// Set one subarray's die temperature (scenario driver / telemetry
    /// ingest). Returns false for unknown ids.
    pub fn set_temperature(&self, id: SubarrayId, temp_c: f64) -> bool {
        self.with_entry(id, |e| e.sub.set_temperature(temp_c)).is_some()
    }

    /// Advance simulated wall-clock time on every subarray (retention
    /// decay + aging drift).
    pub fn advance_time(&self, dt_hours: f64) {
        for shard in self.shards_snapshot() {
            let mut entries = lock_clean(&shard.entries);
            for entry in entries.values_mut() {
                entry.sub.advance_time(dt_hours);
            }
        }
    }

    /// Admission control for the serve path: reject typed when the
    /// in-flight bound is full ([`PudError::Overloaded`]) or the
    /// service is draining ([`PudError::Draining`]); otherwise count
    /// the request in-flight until the returned guard drops.
    fn admit_serve(&self) -> Result<ServeGuard<'_>, PudError> {
        let inflight = {
            let mut sched = lock_clean(&self.sched);
            if !sched.accepting {
                drop(sched);
                self.metrics.incr("admission.rejected_draining");
                return Err(PudError::Draining);
            }
            let limit = self.svc.max_inflight_serves;
            if limit > 0 && sched.inflight_serves >= limit {
                let inflight = sched.inflight_serves;
                drop(sched);
                self.metrics.incr("admission.rejected");
                return Err(PudError::Overloaded { inflight, limit });
            }
            sched.inflight_serves += 1;
            sched.inflight_serves
        };
        self.metrics.incr("admission.accepted");
        self.metrics.gauge_max("serve.concurrent", inflight as u64);
        Ok(ServeGuard { sched: &self.sched, idle_cv: &self.idle_cv })
    }
}

/// In-flight marker for one admitted serve request: dropping it (on
/// any exit path, panic included) releases the admission slot and
/// wakes a pending drain.
struct ServeGuard<'a> {
    sched: &'a Mutex<Scheduler>,
    idle_cv: &'a Condvar,
}

impl Drop for ServeGuard<'_> {
    fn drop(&mut self) {
        let mut sched = lock_clean(self.sched);
        sched.inflight_serves = sched.inflight_serves.saturating_sub(1);
        drop(sched);
        self.idle_cv.notify_all();
    }
}

/// Arithmetic serving (engines that also execute workloads).
impl<E: CalibEngine + ComputeEngine + Sync> RecalibService<E> {
    /// Resolve `op` through the process-wide
    /// [`PlanCache`](crate::coordinator::plancache::PlanCache) (compile
    /// + lower once per process, `plan.cache.*` metrics) and serve it
    /// on every registered subarray — see [`Self::serve_plan`]. An
    /// invalid op is a request-level error; per-bank faults live
    /// inside the returned outcomes.
    ///
    /// The serve inspects the actual operand values: when their
    /// covering bit-lengths ([`RangeClass`]) are strictly narrower
    /// than the op's compiled width, the width-narrowed plan variant
    /// is resolved from the same cache and served instead —
    /// bit-identical outputs (the operands are inside the derived
    /// ranges by construction), fewer gates and steps. Narrowed serves
    /// are counted by `plan.narrow.served`.
    pub fn serve_workload(
        &self,
        op: PudOp,
        operands: &[Vec<u64>],
    ) -> Result<Vec<WorkloadOutcome>, PudError> {
        let cache = crate::coordinator::plancache::PlanCache::global();
        let compiled = cache.get_or_compile(&op, 0, Some(&*self.metrics))?;
        if operands.len() == op.n_operands() && !operands.is_empty() {
            let ranges: Vec<OperandRange> =
                operands.iter().map(|vals| OperandRange::of_values(vals)).collect();
            let class = RangeClass::of(&ranges);
            if class.narrows(&op) {
                let narrow = cache.get_or_narrow(&compiled.plan, 0, &class, Some(&*self.metrics))?;
                self.metrics.incr("plan.narrow.served");
                return self.serve_plan(&narrow.plan, operands);
            }
        }
        self.serve_plan(&compiled.plan, operands)
    }

    /// Serve one compiled workload batch on every subarray (one
    /// batched engine call, per-bank fault isolation): each bank
    /// executes under its *current* calibration and the error-free
    /// column mask from its most recent battery, stale entries
    /// included — arithmetic never waits on the recalibration queue,
    /// and any number of threads may serve concurrently (up to the
    /// admission bound; see [`PudError::Overloaded`] /
    /// [`PudError::Draining`] for the typed rejections).
    /// `operands` are per-column values broadcast to every bank; a
    /// bank whose geometry disagrees degrades to one `Err` outcome.
    /// Each outcome counts how many masked columns matched the
    /// software golden model (`compute.golden_mismatch` tracks the
    /// shortfall). A plan that did not come out of
    /// `WorkloadPlan::compile` is statically verified first and a
    /// charge-state violation rejects the whole request before any
    /// bank executes (`PudError::Verification`).
    pub fn serve_plan(
        &self,
        plan: &Arc<WorkloadPlan>,
        operands: &[Vec<u64>],
    ) -> Result<Vec<WorkloadOutcome>, PudError> {
        let _guard = self.admit_serve()?;
        crate::pud::verify::admit(plan)?;
        *lock_clean(&self.last_workload) = Some((plan.clone(), Arc::new(operands.to_vec())));
        let redundancy = self.svc.redundancy.max(1);
        let mut ids = Vec::new();
        let mut reqs: Vec<ComputeRequest> = Vec::new();
        for shard in self.shards_snapshot() {
            let entries = lock_clean(&shard.entries);
            for (&id, entry) in entries.iter() {
                ids.push(id);
                let mut req = ComputeRequest::from_subarray(
                    &entry.sub,
                    entry.seed,
                    plan.clone(),
                    entry.calib.clone(),
                    operands.to_vec(),
                );
                // Battery mask ∧ quarantine: a column serves only when
                // both the ECR battery and the fault history trust it.
                let quarantined = entry.quarantine.quarantined_cols() > 0;
                if entry.mask.is_some() || quarantined {
                    let mut mask =
                        entry.mask.clone().unwrap_or_else(|| vec![true; entry.sub.cols]);
                    entry.quarantine.apply(&mut mask);
                    req = req.with_mask(mask);
                }
                if redundancy > 1 {
                    req = req.with_replicas(redundancy);
                }
                reqs.push(req);
            }
        }
        let results = self.metrics.time("compute.serve", || {
            execute_isolated(&self.engine, &reqs, self.threads)
        });
        // The golden model depends only on the plan and the broadcast
        // operands — evaluate the circuit once, not once per bank. A
        // 0-operand plan computes one constant; a bank that executed
        // successfully at a different width re-broadcasts it below.
        let shared_cols = operands.first().map(|v| v.len()).unwrap_or(1);
        let golden = plan.golden_outputs(operands, shared_cols);
        let outcomes = ids
            .into_iter()
            .zip(results)
            .map(|(id, result)| {
                let (state, golden_correct, active_cols) = self
                    .with_entry(id, |entry| {
                        let state = entry.state;
                        let (correct, active) = match (&result, &golden) {
                            (Ok(res), Ok(golden)) => {
                                self.metrics.incr("compute.batches");
                                self.metrics.add("fault.flips", res.fault_flips);
                                let active = res.active_cols();
                                self.metrics.add("compute.columns_served", active as u64);
                                let correct = if golden.len() == res.outputs.len() {
                                    res.golden_correct(golden)
                                } else {
                                    // Only reachable for 0-operand plans
                                    // (any width mismatch fails
                                    // execution): compare every column
                                    // to the broadcast constant.
                                    let constant = vec![golden[0]; res.outputs.len()];
                                    res.golden_correct(&constant)
                                };
                                if correct < active {
                                    self.metrics.add(
                                        "compute.golden_mismatch",
                                        (active - correct) as u64,
                                    );
                                }
                                if entry.quarantine.enabled()
                                    && golden.len() == res.outputs.len()
                                {
                                    let bad: Vec<bool> = (0..res.outputs.len())
                                        .map(|c| {
                                            matches!(res.mask.get(c), Some(true))
                                                && res.outputs[c] != golden[c]
                                        })
                                        .collect();
                                    let delta = entry.quarantine.observe_serve(&bad);
                                    self.metrics.add(
                                        "quarantine.observed_mismatches",
                                        delta.dirty as u64,
                                    );
                                    self.metrics
                                        .add("quarantine.entered", delta.entered as u64);
                                }
                                (correct, active)
                            }
                            _ => {
                                self.metrics.incr("compute.bank_failures");
                                (0, 0)
                            }
                        };
                        (state, correct, active)
                    })
                    .unwrap_or((EntryState::Uncalibrated, 0, 0));
                WorkloadOutcome { id, state, result, golden_correct, active_cols }
            })
            .collect();
        Ok(outcomes)
    }

    /// Replay the last served workload **unmasked** on every subarray
    /// and feed each column's golden verdict into its quarantine:
    /// mismatching columns strike toward (or stay in) quarantine,
    /// clean quarantined columns count toward hysteresis release. A
    /// scrub replays exactly what serving runs, so it observes exactly
    /// the corruption serving would absorb — including duty-cycled
    /// intermittent columns that a one-shot spot check misses. No-op
    /// (empty result) before the first served workload.
    pub fn scrub(&self) -> Vec<ScrubOutcome> {
        self.scrub_pending.store(false, Ordering::Relaxed);
        let last = lock_clean(&self.last_workload).clone();
        let Some((plan, operands)) = last else {
            return Vec::new();
        };
        let mut ids = Vec::new();
        let mut reqs: Vec<ComputeRequest> = Vec::new();
        for shard in self.shards_snapshot() {
            let entries = lock_clean(&shard.entries);
            for (&id, entry) in entries.iter() {
                ids.push(id);
                reqs.push(ComputeRequest::from_subarray(
                    &entry.sub,
                    entry.seed,
                    plan.clone(),
                    entry.calib.clone(),
                    operands.as_ref().clone(),
                ));
            }
        }
        let results = self.metrics.time("service.scrub", || {
            execute_isolated(&self.engine, &reqs, self.threads)
        });
        self.metrics.incr("scrub.passes");
        let shared_cols = operands.first().map(|v| v.len()).unwrap_or(1);
        let golden = plan.golden_outputs(&operands, shared_cols);
        ids.into_iter()
            .zip(results)
            .map(|(id, result)| {
                let (result, delta) = self
                    .with_entry(id, |entry| match (result, &golden) {
                        (Ok(res), Ok(golden)) if golden.len() == res.outputs.len() => {
                            let bad: Vec<bool> = (0..res.outputs.len())
                                .map(|c| res.outputs[c] != golden[c])
                                .collect();
                            let delta = entry.quarantine.observe_scrub(&bad);
                            self.metrics.add("fault.flips", res.fault_flips);
                            self.metrics.add("scrub.dirty_cols", delta.dirty as u64);
                            self.metrics.add("quarantine.entered", delta.entered as u64);
                            self.metrics.add("quarantine.released", delta.released as u64);
                            (Ok(()), delta)
                        }
                        (Ok(_), Ok(_)) => (
                            Err("scrub golden width mismatch".to_string()),
                            QuarantineDelta::default(),
                        ),
                        (Ok(_), Err(e)) => (Err(format!("{e}")), QuarantineDelta::default()),
                        (Err(e), _) => {
                            self.metrics.incr("scrub.bank_failures");
                            (Err(e), QuarantineDelta::default())
                        }
                    })
                    .unwrap_or_else(|| {
                        (Err("entry disappeared".to_string()), QuarantineDelta::default())
                    });
                ScrubOutcome { id, result, delta }
            })
            .collect()
    }

    /// One maintenance tick: evaluate drift signals
    /// ([`Self::poll_drift`]) and, when the scrub cadence
    /// (`ServiceConfig::scrub_every`) fires, run the scrub pass. The
    /// [`ServiceServer`] ticker calls this every
    /// `ServiceConfig::maintain_every_ms`.
    pub fn maintain(&self) -> (Vec<(SubarrayId, DriftSignal)>, Vec<ScrubOutcome>) {
        let signals = self.poll_drift();
        let scrubbed = if self.scrub_pending() { self.scrub() } else { Vec::new() };
        (signals, scrubbed)
    }
}

/// One recalibration worker: block on the queue, claim jobs, run them
/// panic-contained, and account `active_jobs` so drain can wait for
/// quiescence.
fn worker_loop<E: CalibEngine + Sync>(svc: &RecalibService<E>) {
    loop {
        let id = {
            let mut sched = lock_clean(&svc.sched);
            loop {
                if sched.stop {
                    return;
                }
                if let Some(id) = sched.queue.pop_front() {
                    sched.active_jobs += 1;
                    break id;
                }
                sched = svc.job_cv.wait(sched).unwrap_or_else(|p| p.into_inner());
            }
        };
        // The engine call inside is already panic-contained; this
        // outer containment guards the bookkeeping, so a worker thread
        // can never die and strand `active_jobs`.
        if worker::run_contained(|| svc.run_one_background(id)).is_err() {
            svc.metrics.incr("recalib.worker_panics");
        }
        let mut sched = lock_clean(&svc.sched);
        sched.active_jobs -= 1;
        drop(sched);
        svc.idle_cv.notify_all();
    }
}

/// The maintenance ticker: periodic [`RecalibService::maintain`]
/// (drift polls + scrub cadence) until drain/stop.
fn maintenance_loop<E: CalibEngine + ComputeEngine + Sync>(svc: &RecalibService<E>) {
    let interval = Duration::from_millis(svc.svc.maintain_every_ms.max(1));
    loop {
        {
            let sched = lock_clean(&svc.sched);
            if sched.stop || !sched.accepting {
                return;
            }
        }
        if worker::run_contained(|| svc.maintain()).is_err() {
            svc.metrics.incr("recalib.worker_panics");
        }
        let sched = lock_clean(&svc.sched);
        if sched.stop || !sched.accepting {
            return;
        }
        let _ = svc
            .tick_cv
            .wait_timeout(sched, interval)
            .unwrap_or_else(|p| p.into_inner());
    }
}

/// Background threads over a shared [`RecalibService`]: N
/// recalibration workers draining the drift queue plus one maintenance
/// ticker, all owned by this handle. Serving keeps going through the
/// shared `Arc<RecalibService<E>>` from any thread; [`Self::drain`] /
/// [`Self::shutdown`] stop admission, finish work, join every thread
/// and return the persisted store. Dropping an undrained server
/// performs a fast shutdown (joins threads, abandons queued jobs).
pub struct ServiceServer<E: CalibEngine + ComputeEngine + Send + Sync + 'static> {
    service: Arc<RecalibService<E>>,
    handles: Vec<JoinHandle<()>>,
}

impl<E: CalibEngine + ComputeEngine + Send + Sync + 'static> ServiceServer<E> {
    /// Spawn `workers.max(1)` recalibration worker threads plus the
    /// maintenance ticker over `service` (restoring admission if a
    /// previous server on the same service had drained it).
    pub fn start(service: Arc<RecalibService<E>>, workers: usize) -> Self {
        {
            let mut sched = lock_clean(&service.sched);
            sched.accepting = true;
            sched.stop = false;
        }
        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let svc = service.clone();
            handles.push(std::thread::spawn(move || worker_loop(svc.as_ref())));
        }
        let svc = service.clone();
        handles.push(std::thread::spawn(move || maintenance_loop(svc.as_ref())));
        Self { service, handles }
    }

    /// The shared service (serve / inspect from any thread).
    pub fn service(&self) -> Arc<RecalibService<E>> {
        self.service.clone()
    }

    /// Graceful drain: stop admitting serves, let in-flight serves and
    /// **every queued recalibration** finish, join all threads, and
    /// return the persisted store snapshot. Records `drain.*` metrics
    /// (`drain.pending_jobs`, `drain.persisted_entries`, the
    /// `drain.seconds` timer).
    pub fn drain(mut self) -> CalibStore {
        self.stop_and_persist(true)
    }

    /// Fast shutdown: like [`Self::drain`] but queued-not-yet-running
    /// jobs are abandoned (`drain.abandoned_jobs`; their entries
    /// re-queue from drift state on the next boot's polls). In-flight
    /// serves and running jobs still finish.
    pub fn shutdown(mut self) -> CalibStore {
        self.stop_and_persist(false)
    }

    fn stop_and_persist(&mut self, finish_queue: bool) -> CalibStore {
        let service = self.service.clone();
        let handles = std::mem::take(&mut self.handles);
        service.metrics.time("drain.seconds", || {
            let (pending, abandoned) = {
                let mut sched = lock_clean(&service.sched);
                sched.accepting = false;
                let pending = sched.queue.len() + sched.active_jobs;
                let abandoned: Vec<SubarrayId> = if finish_queue {
                    Vec::new()
                } else {
                    sched.queue.drain(..).collect()
                };
                (pending, abandoned)
            };
            service.metrics.add("drain.pending_jobs", pending as u64);
            if !finish_queue {
                service.metrics.add("drain.abandoned_jobs", abandoned.len() as u64);
                for id in abandoned {
                    // Un-mark so the next boot's polls re-queue them.
                    service.with_entry(id, |e| e.queued = false);
                }
            }
            service.job_cv.notify_all();
            service.tick_cv.notify_all();
            // Quiesce: workers keep claiming until the queue is empty
            // (drain) or already cleared (shutdown); serve guards
            // release their slots. The timeout re-checks the predicate
            // even if a wake-up is missed, so drain always terminates.
            {
                let mut sched = lock_clean(&service.sched);
                while sched.inflight_serves > 0
                    || sched.active_jobs > 0
                    || !sched.queue.is_empty()
                {
                    sched = service
                        .idle_cv
                        .wait_timeout(sched, Duration::from_millis(50))
                        .unwrap_or_else(|p| p.into_inner())
                        .0;
                }
                sched.stop = true;
            }
            service.job_cv.notify_all();
            service.tick_cv.notify_all();
            for h in handles {
                let _ = h.join();
            }
            let store = service.snapshot_store();
            service
                .metrics
                .add("drain.persisted_entries", store.entries.len() as u64);
            store
        })
    }
}

impl<E: CalibEngine + ComputeEngine + Send + Sync + 'static> Drop for ServiceServer<E> {
    fn drop(&mut self) {
        if self.handles.is_empty() {
            return; // drained/shut down explicitly
        }
        let _ = self.stop_and_persist(false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::algorithm::NativeEngine;

    fn service(banks: usize, cols: usize) -> RecalibService<NativeEngine> {
        let cfg = DeviceConfig::default();
        let svc = ServiceConfig { serve_samples: 512, ..ServiceConfig::default() };
        service_with(NativeEngine::new(cfg.clone()), cfg, svc, banks, cols)
    }

    fn service_with<E: CalibEngine + Sync>(
        engine: E,
        cfg: DeviceConfig,
        svc: ServiceConfig,
        banks: usize,
        cols: usize,
    ) -> RecalibService<E> {
        let s = RecalibService::new(cfg, svc, engine).unwrap();
        for b in 0..banks {
            s.register(SubarrayId::new(0, b, 0), 32, cols, 0x5EED);
        }
        s
    }

    /// Drift policy with the environment-match fast path enabled.
    fn env_match_cfg(temp_c: f64, hours: f64) -> ServiceConfig {
        let mut svc = ServiceConfig { serve_samples: 512, ..ServiceConfig::default() };
        svc.policy.env_match_temp_c = temp_c;
        svc.policy.env_match_hours = hours;
        svc
    }

    /// Engine whose spot-check path must never run: calibration
    /// delegates, but any ECR measurement is an injected failure.
    struct NoSpotCheckEngine {
        inner: NativeEngine,
    }

    impl CalibEngine for NoSpotCheckEngine {
        fn backend(&self) -> &'static str {
            "no-spot-check"
        }

        fn calibrate_batch(&self, reqs: &[CalibRequest]) -> anyhow::Result<Vec<Calibration>> {
            self.inner.calibrate_batch(reqs)
        }

        fn measure_ecr_batch(&self, _reqs: &[EcrRequest]) -> anyhow::Result<Vec<EcrReport>> {
            panic!("spot check must be skipped on an env-matched load");
        }
    }

    #[test]
    fn cold_start_calibrates_and_persists() {
        let s = service(2, 512);
        assert_eq!(s.pending(), 2);
        assert!(s.ids().iter().all(|&id| s.state(id) == Some(EntryState::Uncalibrated)));
        let done = s.run_pending(usize::MAX);
        assert_eq!(done.len(), 2);
        assert!(done.iter().all(|(_, r)| r.is_ok()));
        assert!(s.ids().iter().all(|&id| s.state(id) == Some(EntryState::Accepted)));
        assert_eq!(s.pending(), 0);
        assert_eq!(s.snapshot_store().entries.len(), 2);
        assert_eq!(s.metrics.counter("recalib.completed"), 2);
    }

    #[test]
    fn load_accepts_good_entries_and_skips_their_cold_start() {
        let warm = service(2, 512);
        warm.run_pending(usize::MAX);
        let store = warm.snapshot_store();

        // "Reboot": a fresh service over the same manufactured device.
        let s = service(2, 512);
        let outcomes = s.load_store(&store);
        for (id, o) in &outcomes {
            assert!(matches!(o, LoadOutcome::Accepted { .. }), "{id:?}: {o:?}");
        }
        assert_eq!(s.metrics.counter("recalib.accepted_on_load"), 2);
        assert_eq!(s.metrics.counter("recalib.rejected_on_load"), 0);
        assert_eq!(s.pending(), 0);
        // The loaded levels are bit-identical to the persisted ones.
        for &id in &s.ids() {
            assert_eq!(
                s.calibration(id).unwrap().levels,
                warm.calibration(id).unwrap().levels
            );
        }
        // The stale queue entries from registration are skipped.
        assert!(s.run_pending(usize::MAX).is_empty());
    }

    #[test]
    fn env_match_fast_accepts_without_spot_check() {
        let warm = service(2, 256);
        warm.run_pending(usize::MAX);
        let store = warm.snapshot_store();

        // Same device, same environment: the fast path must accept
        // every entry without spending a single ECR measurement — the
        // engine's measurement path is an injected panic.
        let cfg = DeviceConfig::default();
        let s = service_with(
            NoSpotCheckEngine { inner: NativeEngine::new(cfg.clone()) },
            cfg,
            env_match_cfg(1.0, 1.0),
            2,
            256,
        );
        let outcomes = s.load_store(&store);
        for (id, o) in &outcomes {
            match o {
                LoadOutcome::AcceptedOnEnv { temp_delta_c, hours_delta } => {
                    assert!(*temp_delta_c <= 1.0 && *hours_delta <= 1.0, "{id:?}: {o:?}");
                }
                other => panic!("{id:?}: expected AcceptedOnEnv, got {other:?}"),
            }
        }
        assert_eq!(s.metrics.counter("recalib.accepted_on_env"), 2);
        assert_eq!(s.metrics.counter("recalib.accepted_on_load"), 0);
        assert_eq!(s.pending(), 0);
        for &id in &s.ids() {
            assert_eq!(s.state(id), Some(EntryState::Accepted));
            assert_eq!(
                s.calibration(id).unwrap().levels,
                warm.calibration(id).unwrap().levels
            );
        }
        // The cold-start queue entries were satisfied by the load.
        assert!(s.run_pending(usize::MAX).is_empty());
    }

    #[test]
    fn env_near_miss_falls_back_to_the_spot_check() {
        let warm = service(1, 256);
        warm.run_pending(usize::MAX);
        let store = warm.snapshot_store();

        let cfg = DeviceConfig::default();
        let s = service_with(
            NativeEngine::new(cfg.clone()),
            cfg,
            env_match_cfg(1.0, 1.0),
            1,
            256,
        );
        // Two hours of retention age: outside the one-hour match
        // tolerance, inside every drift-policy bound — the entry is
        // still good, it just has to prove it with a spot check.
        s.advance_time(2.0);
        let outcomes = s.load_store(&store);
        assert!(
            matches!(outcomes[0].1, LoadOutcome::Accepted { .. }),
            "near miss must spot check: {:?}",
            outcomes[0].1
        );
        assert_eq!(s.metrics.counter("recalib.accepted_on_env"), 0);
        assert_eq!(s.metrics.counter("recalib.accepted_on_load"), 1);
    }

    #[test]
    fn v1_entry_without_env_spot_checks_even_with_fast_path_enabled() {
        let warm = service(1, 256);
        warm.run_pending(usize::MAX);
        let id = SubarrayId::new(0, 0, 0);
        // A v1 store entry: raw calibration, no environment metadata.
        let mut store = CalibStore::default();
        store.insert(id, &warm.calibration(id).unwrap());
        assert!(store.stored_env(id).is_none());

        let cfg = DeviceConfig::default();
        let s = service_with(
            NativeEngine::new(cfg.clone()),
            cfg,
            env_match_cfg(10.0, 1000.0),
            1,
            256,
        );
        let outcomes = s.load_store(&store);
        assert!(
            matches!(outcomes[0].1, LoadOutcome::Accepted { .. }),
            "v1 entries carry no env to match: {:?}",
            outcomes[0].1
        );
        assert_eq!(s.metrics.counter("recalib.accepted_on_env"), 0);
        assert_eq!(s.metrics.counter("recalib.accepted_on_load"), 1);
    }

    #[test]
    fn load_rejects_tampered_entries() {
        let warm = service(1, 512);
        warm.run_pending(usize::MAX);
        let mut store = warm.snapshot_store();
        let id = SubarrayId::new(0, 0, 0);
        // Pin every column to the lowest lattice level: a maximally
        // wrong calibration that the spot check must catch.
        store.entries.get_mut(&id).unwrap().levels = vec![0; 512];

        let s = service(1, 512);
        let outcomes = s.load_store(&store);
        assert!(matches!(outcomes[0].1, LoadOutcome::Rejected { spot_ecr } if spot_ecr > 0.5));
        assert_eq!(s.metrics.counter("recalib.rejected_on_load"), 1);
        assert_eq!(s.state(id), Some(EntryState::Uncalibrated));
        // Still queued from registration: recalibration repairs it.
        assert_eq!(s.pending(), 1);
        s.run_pending(usize::MAX);
        assert_eq!(s.state(id), Some(EntryState::Accepted));
    }

    #[test]
    fn geometry_mismatch_is_incompatible_not_a_miss() {
        let warm = service(1, 512);
        warm.run_pending(usize::MAX);
        let store = warm.snapshot_store();
        let s = service(1, 256);
        let outcomes = s.load_store(&store);
        assert!(matches!(&outcomes[0].1, LoadOutcome::Incompatible(e) if e.contains("512")));
        assert_eq!(s.metrics.counter("recalib.rejected_on_load"), 1);
    }

    #[test]
    fn serve_feeds_monitors_without_touching_the_queue() {
        let s = service(1, 512);
        s.run_pending(usize::MAX);
        let out = s.serve();
        assert_eq!(out.len(), 1);
        assert!(out[0].report.is_ok());
        assert_eq!(out[0].state, EntryState::Accepted);
        assert_eq!(s.metrics.counter("serve.batches"), 1);
        assert_eq!(s.pending(), 0);
        // A quiet environment raises no drift signals.
        assert!(s.poll_drift().is_empty());
    }

    #[test]
    fn temperature_excursion_schedules_background_recalibration() {
        let s = service(2, 512);
        s.run_pending(usize::MAX);
        let hot = SubarrayId::new(0, 1, 0);
        assert!(s.set_temperature(hot, 85.0));
        let signals = s.poll_drift();
        assert_eq!(signals.len(), 1);
        assert_eq!(signals[0].0, hot);
        assert!(matches!(signals[0].1, DriftSignal::TemperatureExcursion { .. }));
        assert_eq!(s.state(hot), Some(EntryState::Stale));
        assert_eq!(s.metrics.counter("recalib.scheduled"), 1);
        // A shutdown now must not lose the stale bank's last-known-good
        // entry: snapshots persist everything except Uncalibrated.
        assert_eq!(s.snapshot_store().entries.len(), 2);
        // Stale entries keep serving while queued.
        assert!(s.serve()[1].report.is_ok());
        let done = s.run_pending(usize::MAX);
        assert_eq!(done.len(), 1);
        assert!(done[0].1.is_ok());
        assert_eq!(s.state(hot), Some(EntryState::Accepted));
        // Re-anchored at the hot temperature: no further signal.
        assert!(s.poll_drift().is_empty());
    }

    #[test]
    fn request_recalibration_marks_stale_and_queues() {
        let s = service(1, 128);
        s.run_pending(usize::MAX);
        let id = SubarrayId::new(0, 0, 0);
        assert!(s.request_recalibration(id));
        assert_eq!(s.state(id), Some(EntryState::Stale));
        assert_eq!(s.pending(), 1);
        assert_eq!(s.metrics.counter("recalib.requested"), 1);
        // Idempotent while queued: no duplicate queue element.
        assert!(s.request_recalibration(id));
        assert_eq!(s.pending(), 1);
        let done = s.run_pending(usize::MAX);
        assert_eq!(done.len(), 1);
        assert_eq!(s.state(id), Some(EntryState::Accepted));
        assert!(!s.request_recalibration(SubarrayId::new(7, 7, 7)));
    }

    #[test]
    fn unknown_id_set_temperature_is_reported() {
        let s = service(1, 128);
        assert!(!s.set_temperature(SubarrayId::new(7, 7, 7), 60.0));
    }

    #[test]
    fn serve_workload_runs_under_current_masks() {
        use crate::pud::plan::PudOp;
        let cols = 64;
        let s = service(2, cols);
        s.run_pending(usize::MAX);
        // A served battery establishes each bank's error-free mask.
        s.serve();
        // width 2: the add2 plan needs ~10 scratch rows, well inside
        // the 16 the test geometry's data region provides.
        let a: Vec<u64> = (0..cols as u64).map(|c| c % 4).collect();
        let b: Vec<u64> = (0..cols as u64).map(|c| (c * 5 + 2) % 4).collect();
        let out = s
            .serve_workload(PudOp::Add { width: 2 }, &[a.clone(), b.clone()])
            .unwrap();
        assert_eq!(out.len(), 2);
        for o in &out {
            let res = o.result.as_ref().expect("served");
            assert_eq!(o.state, EntryState::Accepted);
            // The battery-derived mask restricts reporting.
            assert!(res.mask.len() == cols && o.active_cols <= cols);
            assert!(o.golden_correct <= o.active_cols);
            assert!(res.elapsed_ns > 0.0);
        }
        assert_eq!(s.metrics.counter("compute.batches"), 2);
        assert_eq!(s.metrics.counter("compute.bank_failures"), 0);
        assert_eq!(s.metrics.counter("admission.accepted"), 1);
        assert_eq!(s.metrics.counter("serve.concurrent"), 1);
        // An invalid op fails the request, not the banks.
        assert!(s.serve_workload(PudOp::Add { width: 0 }, &[a, b]).is_err());
        assert_eq!(s.metrics.counter("compute.bank_failures"), 0);
    }

    #[test]
    fn drained_service_rejects_serves_with_a_typed_error() {
        use crate::pud::plan::PudOp;
        let cols = 32;
        let s = Arc::new(service(1, cols));
        s.run_pending(usize::MAX);
        let server = ServiceServer::start(s.clone(), 1);
        assert!(s.is_accepting());
        let store = server.drain();
        assert_eq!(store.entries.len(), 1);
        assert!(!s.is_accepting());
        let a: Vec<u64> = (0..cols as u64).map(|c| c % 4).collect();
        let err = s
            .serve_workload(PudOp::Add { width: 2 }, &[a.clone(), a])
            .unwrap_err();
        assert_eq!(err, PudError::Draining);
        assert_eq!(s.metrics.counter("admission.rejected_draining"), 1);
        assert!(s.metrics.counter("drain.persisted_entries") >= 1);
    }

    #[test]
    fn quarantine_hysteresis_enters_and_releases() {
        let mut q = Quarantine::new(4, 2, 2);
        assert!(q.enabled());
        let bad = vec![false, true, false, true];
        assert_eq!(
            q.observe_serve(&bad),
            QuarantineDelta { entered: 0, released: 0, dirty: 2 }
        );
        // The second strike quarantines both dirty columns.
        assert_eq!(q.observe_serve(&bad).entered, 2);
        assert_eq!(q.quarantined_cols(), 2);
        assert!(q.is_quarantined(1) && q.is_quarantined(3));
        let mut mask = vec![true; 4];
        q.apply(&mut mask);
        assert_eq!(mask, vec![true, false, true, false]);
        // One clean scrub is not enough to release (hysteresis)...
        let clean = vec![false; 4];
        assert_eq!(q.observe_scrub(&clean).released, 0);
        // ...a dirty scrub resets column 1's progress while column 3
        // reaches two consecutive clean passes and is released.
        let dirty1 = vec![false, true, false, false];
        assert_eq!(
            q.observe_scrub(&dirty1),
            QuarantineDelta { entered: 0, released: 1, dirty: 1 }
        );
        assert!(q.is_quarantined(1) && !q.is_quarantined(3));
        // Column 1 needs two fresh consecutive clean passes.
        assert_eq!(q.observe_scrub(&clean).released, 0);
        assert_eq!(q.observe_scrub(&clean).released, 1);
        assert_eq!(q.quarantined_cols(), 0);
        // Release clears the strike history: one new mismatch does not
        // re-quarantine.
        assert_eq!(q.observe_serve(&bad).entered, 0);
    }

    #[test]
    fn disabled_quarantine_is_inert() {
        let mut q = Quarantine::new(4, 0, 2);
        assert!(!q.enabled());
        let bad = vec![true; 4];
        for _ in 0..5 {
            assert_eq!(q.observe_serve(&bad), QuarantineDelta::default());
            assert_eq!(q.observe_scrub(&bad), QuarantineDelta::default());
        }
        assert_eq!(q.quarantined_cols(), 0);
        let mut mask = vec![true; 4];
        q.apply(&mut mask);
        assert_eq!(mask, vec![true; 4]);
    }

    #[test]
    fn scrub_observations_strike_toward_quarantine() {
        let mut q = Quarantine::new(2, 2, 1);
        let bad = vec![true, false];
        assert_eq!(q.observe_scrub(&bad).entered, 0);
        assert_eq!(q.observe_scrub(&bad).entered, 1);
        assert!(q.is_quarantined(0));
        // clean_to_release is clamped to at least one pass.
        assert_eq!(q.observe_scrub(&[false, false]).released, 1);
    }

    #[test]
    fn scrub_cadence_fires_through_maintenance_polls() {
        use crate::pud::plan::PudOp;
        let cols = 32;
        let cfg = DeviceConfig::default();
        let svc = ServiceConfig {
            serve_samples: 256,
            quarantine_strikes: 2,
            scrub_every: 2,
            ..ServiceConfig::default()
        };
        let s = RecalibService::new(cfg.clone(), svc, NativeEngine::new(cfg)).unwrap();
        s.register(SubarrayId::new(0, 0, 0), 32, cols, 0x5EED);
        s.run_pending(usize::MAX);
        // Poll 1: cadence not due yet.
        let (_, sc) = s.maintain();
        assert!(sc.is_empty() && !s.scrub_pending());
        // Poll 2: due, but nothing served yet — the pass is empty and
        // the flag still clears.
        let (_, sc) = s.maintain();
        assert!(sc.is_empty() && !s.scrub_pending());
        assert_eq!(s.metrics.counter("scrub.passes"), 0);
        // Serve a workload, then the next due poll scrubs it.
        let a: Vec<u64> = (0..cols as u64).map(|c| c % 4).collect();
        let b: Vec<u64> = (0..cols as u64).map(|c| (c * 5 + 2) % 4).collect();
        s.serve_workload(PudOp::Add { width: 2 }, &[a, b]).unwrap();
        let (_, sc) = s.maintain(); // poll 3: not due
        assert!(sc.is_empty());
        let (_, sc) = s.maintain(); // poll 4: due
        assert_eq!(sc.len(), 1);
        assert!(sc[0].result.is_ok());
        assert_eq!(s.metrics.counter("scrub.passes"), 1);
    }

    #[test]
    fn snapshot_persists_calibration_environment_metadata() {
        let s = service(1, 128);
        s.run_pending(usize::MAX);
        let id = SubarrayId::new(0, 0, 0);
        // An excursion past the policy bound schedules recalibration;
        // the repaired entry re-anchors its monitor at the hot
        // temperature, which is what the v2 store must record.
        s.set_temperature(id, 85.0);
        assert_eq!(s.poll_drift().len(), 1);
        s.run_pending(usize::MAX);
        let store = s.snapshot_store();
        let env = store.stored_env(id).expect("v2 entries carry an environment");
        assert_eq!(env.temp_c, 85.0);
    }
}
