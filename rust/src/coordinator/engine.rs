//! The PJRT-backed calibration/measurement engine and the device-level
//! coordinator.
//!
//! One Algorithm-1 iteration = one executable call (`maj5_step_*`):
//! the sampling batch, bias computation and level update are fused into
//! a single AOT graph (L2) embedding the charge-share/sense Pallas
//! kernel (L1), so the Rust<->PJRT boundary is crossed 20 times per
//! subarray calibration — the same count as the paper's host<->FPGA
//! round trips. ECR measurement is one call (`maj*_ecr_*`, a scanned
//! 8,192-sample graph).

use anyhow::{anyhow, Result};
use std::sync::Arc;

use crate::analysis::ecr::EcrReport;
use crate::calib::algorithm::{const_q, CalibParams, Calibration};
use crate::calib::lattice::{ConfigKind, FracConfig, OffsetLattice};
use crate::config::device::DeviceConfig;
use crate::config::system::SystemConfig;
use crate::coordinator::metrics::Metrics;
use crate::dram::sense_amp::SenseAmps;
use crate::dram::temperature::Environment;
use crate::runtime::buffers;
use crate::runtime::{Executable, Runtime};
use crate::util::rng::{derive_seed, Rng};

/// The coordinator's view of one subarray on the PJRT path: the
/// sense-amplifier state (thresholds) and environment — cell charges
/// live inside the sampling graphs.
#[derive(Clone, Debug)]
pub struct ColumnBank {
    pub sa: SenseAmps,
    pub env: Environment,
    pub seed: u64,
}

impl ColumnBank {
    /// Same seed derivation as `Device`/`Subarray`, so native and PJRT
    /// paths see identical variation fields.
    pub fn new(cfg: &DeviceConfig, cols: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        Self {
            sa: SenseAmps::new(cfg, cols, &mut rng),
            env: Environment::nominal(cfg.t_cal),
            seed,
        }
    }

    pub fn thresholds(&self, cfg: &DeviceConfig) -> Vec<f32> {
        self.sa.effective_thresholds(cfg, &self.env)
    }

    pub fn cols(&self) -> usize {
        self.sa.cols()
    }
}

/// PJRT-backed engine.
pub struct PjrtEngine {
    pub rt: Arc<Runtime>,
    pub cfg: DeviceConfig,
    pub metrics: Arc<Metrics>,
}

impl PjrtEngine {
    pub fn new(rt: Arc<Runtime>, cfg: DeviceConfig) -> Self {
        Self { rt, cfg, metrics: Arc::new(Metrics::new()) }
    }

    /// Find the artifact `maj{m}_{kind}_*` whose baked column count
    /// matches; errors out with a rebuild hint otherwise.
    fn find(&self, m: usize, kind: &str, cols: usize) -> Result<Arc<Executable>> {
        for name in self.rt.artifact_names() {
            if !name.starts_with(&format!("maj{m}_{kind}_")) {
                continue;
            }
            let exe = self.rt.load(&name)?;
            if exe.meta_usize("cols") == Some(cols) {
                return Ok(exe);
            }
        }
        Err(anyhow!(
            "no maj{m}_{kind} artifact for {cols} columns — rebuild with \
             `make artifacts` (use --full for 65,536 columns)"
        ))
    }

    /// Common literal prologue shared by step and ECR graphs.
    fn lattice_args(&self, calib: &Calibration) -> Result<Vec<xla::Literal>> {
        let lat = &calib.lattice;
        Ok(vec![
            buffers::i32_vec(&calib.levels.iter().map(|&v| v as i32).collect::<Vec<_>>()),
            buffers::f32_array(&lat.bits_table_f32(), &[8, 3])?,
            buffers::f32_vec(&lat.config.fracs.map(|x| x as f32)),
            buffers::f32_scalar(self.cfg.frac_r as f32),
        ])
    }

    /// Algorithm 1 on the PJRT path.
    pub fn calibrate(
        &self,
        bank: &ColumnBank,
        fc: &FracConfig,
        params: &CalibParams,
    ) -> Result<Calibration> {
        let cols = bank.cols();
        let lattice = OffsetLattice::build(&self.cfg, fc);
        let mut calib = Calibration::uniform(lattice, cols);
        if fc.kind == ConfigKind::Baseline {
            return Ok(calib);
        }
        let exe = self.find(5, "step", cols)?;
        anyhow::ensure!(
            exe.meta_usize("samples") == Some(params.samples as usize)
                || exe.meta_usize("samples").is_some(),
            "step artifact missing sample metadata"
        );
        let thr = bank.thresholds(&self.cfg);
        let thr_lit = buffers::f32_vec(&thr);
        for iter in 0..params.iterations {
            let seed = derive_seed(params.seed, &[bank.seed, iter as u64]) as u32;
            let mut args = vec![buffers::u32_scalar(seed)];
            args.extend(self.lattice_args(&calib)?);
            args.push(buffers::f32_scalar(const_q(5) as f32));
            args.push(thr_lit.clone());
            args.push(buffers::f32_scalar(self.cfg.sigma_noise as f32));
            args.push(buffers::f32_scalar(params.tau as f32));
            args.push(buffers::f32_scalar(1.0)); // update
            let out = self.metrics.time("pjrt.step", || exe.run(&args))?;
            self.metrics.incr("pjrt.step.calls");
            let new_levels = buffers::to_i32_vec(&out[0])?;
            for (lv, nl) in calib.levels.iter_mut().zip(&new_levels) {
                *lv = *nl as u8;
            }
        }
        Ok(calib)
    }

    /// Mass ECR measurement (the paper's 8,192 random inputs) in one
    /// executable call.
    pub fn measure_ecr(
        &self,
        bank: &ColumnBank,
        calib: &Calibration,
        m: usize,
        seed: u64,
    ) -> Result<EcrReport> {
        let cols = bank.cols();
        let exe = self.find(m, "ecr", cols)?;
        let total = exe
            .meta_usize("total_samples")
            .ok_or_else(|| anyhow!("ecr artifact missing total_samples"))?;
        let thr = bank.thresholds(&self.cfg);
        let seed32 = derive_seed(seed, &[bank.seed, m as u64]) as u32;
        let mut args = vec![buffers::u32_scalar(seed32)];
        args.extend(self.lattice_args(calib)?);
        args.push(buffers::f32_scalar(const_q(m) as f32));
        args.push(buffers::f32_vec(&thr));
        args.push(buffers::f32_scalar(self.cfg.sigma_noise as f32));
        let out = self.metrics.time("pjrt.ecr", || exe.run(&args))?;
        self.metrics.incr("pjrt.ecr.calls");
        let err = buffers::to_i32_vec(&out[0])?;
        Ok(EcrReport::from_error_counts(
            err.into_iter().map(|e| e.max(0) as u32).collect(),
            total as u32,
        ))
    }
}

/// Per-bank measurement outcome (the unit Table I aggregates).
#[derive(Clone, Debug)]
pub struct BankOutcome {
    pub bank_seed: u64,
    /// MAJ5 ECR, baseline / PUDTune.
    pub ecr5_base: f64,
    pub ecr5_tune: f64,
    /// Arithmetic (MAJ5 ∧ MAJ3) ECR, baseline / PUDTune.
    pub ecr_arith_base: f64,
    pub ecr_arith_tune: f64,
}

/// Device-level coordinator: fans per-bank jobs across workers.
pub struct DeviceCoordinator {
    pub cfg: DeviceConfig,
    pub sys: SystemConfig,
    pub engine: Arc<PjrtEngine>,
}

impl DeviceCoordinator {
    pub fn new(cfg: DeviceConfig, sys: SystemConfig, engine: Arc<PjrtEngine>) -> Self {
        Self { cfg, sys, engine }
    }

    /// Calibrate + measure one bank under baseline and PUDTune configs.
    pub fn bank_outcome(
        &self,
        bank_seed: u64,
        base: &FracConfig,
        tune: &FracConfig,
        params: &CalibParams,
    ) -> Result<BankOutcome> {
        let bank = ColumnBank::new(&self.cfg, self.sys.cols, bank_seed);
        let base_cal = base.uncalibrated(&self.cfg, bank.cols());
        let tune_cal = self.engine.calibrate(&bank, tune, params)?;
        let e5b = self.engine.measure_ecr(&bank, &base_cal, 5, 0xECB)?;
        let e5t = self.engine.measure_ecr(&bank, &tune_cal, 5, 0xECB)?;
        let e3b = self.engine.measure_ecr(&bank, &base_cal, 3, 0xEC3)?;
        let e3t = self.engine.measure_ecr(&bank, &tune_cal, 3, 0xEC3)?;
        Ok(BankOutcome {
            bank_seed,
            ecr5_base: e5b.ecr(),
            ecr5_tune: e5t.ecr(),
            ecr_arith_base: e5b.intersect(&e3b).ecr(),
            ecr_arith_tune: e5t.intersect(&e3t).ecr(),
        })
    }

    /// All banks of the configured system.
    ///
    /// Sequential over banks: the `xla` crate's PJRT client is not
    /// `Send`/`Sync` (an `Rc` inside the C wrapper), and the CPU PJRT
    /// backend is internally threaded anyway — the native engine path
    /// (`experiments::run_table1`) is the one that fans banks across
    /// the worker pool.
    pub fn run_banks(
        &self,
        device_seed: u64,
        banks: usize,
        base: &FracConfig,
        tune: &FracConfig,
        params: &CalibParams,
        _threads: usize,
    ) -> Result<Vec<BankOutcome>> {
        (0..banks)
            .map(|b| {
                let seed = derive_seed(device_seed, &[0, b as u64, 0]);
                self.bank_outcome(seed, base, tune, params)
            })
            .collect()
    }
}

/// Mean ECRs across bank outcomes: (maj5 base, maj5 tune, arith base,
/// arith tune).
pub fn mean_ecrs(outcomes: &[BankOutcome]) -> (f64, f64, f64, f64) {
    let n = outcomes.len().max(1) as f64;
    (
        outcomes.iter().map(|o| o.ecr5_base).sum::<f64>() / n,
        outcomes.iter().map(|o| o.ecr5_tune).sum::<f64>() / n,
        outcomes.iter().map(|o| o.ecr_arith_base).sum::<f64>() / n,
        outcomes.iter().map(|o| o.ecr_arith_tune).sum::<f64>() / n,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_bank_matches_subarray_variation() {
        use crate::dram::subarray::Subarray;
        let cfg = DeviceConfig::default();
        let bank = ColumnBank::new(&cfg, 256, 99);
        let sub = Subarray::with_geometry(&cfg, 32, 256, 99);
        assert_eq!(bank.sa.variation.sa_offset, sub.sa.variation.sa_offset);
        assert_eq!(bank.thresholds(&cfg), sub.sa.effective_thresholds(&cfg, &sub.env));
    }

    #[test]
    fn mean_ecr_aggregation() {
        let o = |b: f64, t: f64| BankOutcome {
            bank_seed: 0,
            ecr5_base: b,
            ecr5_tune: t,
            ecr_arith_base: b,
            ecr_arith_tune: t,
        };
        let (b5, t5, ba, ta) = mean_ecrs(&[o(0.4, 0.04), o(0.6, 0.02)]);
        assert!((b5 - 0.5).abs() < 1e-12);
        assert!((t5 - 0.03).abs() < 1e-12);
        assert_eq!(ba, b5);
        assert_eq!(ta, t5);
    }
}
