//! The PJRT-backed calibration/measurement engine and the device-level
//! coordinator.
//!
//! One Algorithm-1 iteration = one executable call (`maj5_step_*`):
//! the sampling batch, bias computation and level update are fused into
//! a single AOT graph (L2) embedding the charge-share/sense Pallas
//! kernel (L1), so the Rust<->PJRT boundary is crossed 20 times per
//! subarray calibration — the same count as the paper's host<->FPGA
//! round trips. ECR measurement is one call (`maj*_ecr_*`, a scanned
//! 8,192-sample graph).
//!
//! ## Batched multi-bank execution
//!
//! Through the [`CalibEngine`] trait this engine is **batch-first**:
//! when every request in a batch shares its Frac configuration (and,
//! for calibration, its Algorithm-1 parameters), the banks'
//! `[cols]`-shaped threshold vectors are stacked into one wide virtual
//! bank and the whole batch runs as **one executable invocation per
//! step** — N banks cost the same number of Rust<->PJRT crossings as
//! one. The AOT graphs already take `[cols]`-shaped threshold inputs,
//! so fusion is pure argument plumbing; when no artifact matches the
//! stacked width the engine falls back to per-bank calls and counts
//! the miss in [`Metrics`] (`pjrt.batch.unfused`).
//!
//! ## Compute: plan → lower → fuse → execute
//!
//! Arithmetic serving follows the same batch-first shape through the
//! canonical lowering pipeline: a [`crate::pud::plan::PudOp`] compiles
//! once into a [`crate::pud::plan::WorkloadPlan`], the plan lowers
//! once into the verifier-checked step program
//! ([`crate::pud::verify::LoweredPlan`], cached on the plan and in the
//! process-wide [`crate::coordinator::plancache::PlanCache`]), and
//! [`ComputeEngine::execute_batch`] **fuses** requests sharing a
//! (plan fingerprint, geometry) group so N banks walk one step stream
//! together instead of fanning out per request. On this PJRT engine
//! the step program executes on a single lazily-built native fallback
//! engine; `pjrt.compute.fallback` counts the **lowered steps** whose
//! class has no fused lowering ([`unfusable_steps`]) — zero for the
//! whole built-in vocabulary — rather than whole batches.

use anyhow::{anyhow, Result};
use std::fmt;
use std::sync::{Arc, OnceLock};

use crate::analysis::ecr::EcrReport;
use crate::calib::algorithm::{const_q, CalibParams, Calibration, NativeEngine};
use crate::calib::engine::{
    BankBatch, CalibEngine, CalibRequest, ComputeEngine, ComputeRequest, ComputeResult,
    EcrRequest,
};
use crate::calib::lattice::{ConfigKind, FracConfig, OffsetLattice};
use crate::config::device::DeviceConfig;
use crate::config::system::SystemConfig;
use crate::coordinator::metrics::Metrics;
use crate::dram::sense_amp::SenseAmps;
use crate::dram::subarray::Subarray;
use crate::dram::temperature::Environment;
use crate::pud::verify::{LoweredPlan, LoweredStep};
use crate::runtime::buffers;
use crate::runtime::{Executable, Runtime};
use crate::util::rng::{derive_seed, Rng};

/// Master-seed tag of the MAJ5 measurement battery (the stream domain
/// `DeviceCoordinator::run_banks` measures Table I's MAJ5 columns on).
pub const ECR_SEED_MAJ5: u64 = 0xECB;
/// Master-seed tag of the MAJ3 battery used for the arithmetic
/// (MAJ5 ∧ MAJ3) intersection.
pub const ECR_SEED_ARITH: u64 = 0xEC3;

/// The coordinator's view of one subarray on the PJRT path: the
/// sense-amplifier state (thresholds) and environment — cell charges
/// live inside the sampling graphs.
#[derive(Clone, Debug)]
pub struct ColumnBank {
    pub sa: SenseAmps,
    pub env: Environment,
    pub seed: u64,
}

impl ColumnBank {
    /// Same seed derivation as `Device`/`Subarray`, so native and PJRT
    /// paths see identical variation fields.
    pub fn new(cfg: &DeviceConfig, cols: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        Self {
            sa: SenseAmps::new(cfg, cols, &mut rng),
            env: Environment::nominal(cfg.t_cal),
            seed,
        }
    }

    /// Snapshot an existing subarray's sense amps + environment (the
    /// sampling paths never read cell charges). `seed` is the seed the
    /// subarray was built from; it selects PJRT stream domains.
    pub fn from_subarray(sub: &Subarray, seed: u64) -> Self {
        Self { sa: sub.sa.clone(), env: sub.env, seed }
    }

    pub fn thresholds(&self, cfg: &DeviceConfig) -> Vec<f32> {
        self.sa.effective_thresholds(cfg, &self.env)
    }

    pub fn cols(&self) -> usize {
        self.sa.cols()
    }
}

/// PJRT-backed engine.
pub struct PjrtEngine {
    pub rt: Arc<Runtime>,
    pub cfg: DeviceConfig,
    pub metrics: Arc<Metrics>,
    /// Lazily-built native engine the compute path falls back to for
    /// step classes with no PJRT artifact — built once, not per call.
    fallback: OnceLock<NativeEngine>,
}

impl PjrtEngine {
    pub fn new(rt: Arc<Runtime>, cfg: DeviceConfig) -> Self {
        Self { rt, cfg, metrics: Arc::new(Metrics::new()), fallback: OnceLock::new() }
    }

    /// The native fallback engine, built on first use and reused for
    /// the engine's lifetime.
    fn fallback_engine(&self) -> &NativeEngine {
        self.fallback.get_or_init(|| NativeEngine::new(self.cfg.clone()))
    }

    /// Find the artifact `maj{m}_{kind}_*` whose baked column count
    /// matches; errors out with a rebuild hint otherwise.
    fn find(&self, m: usize, kind: &str, cols: usize) -> Result<Arc<Executable>> {
        for name in self.rt.artifact_names() {
            if !name.starts_with(&format!("maj{m}_{kind}_")) {
                continue;
            }
            let exe = self.rt.load(&name)?;
            if exe.meta_usize("cols") == Some(cols) {
                return Ok(exe);
            }
        }
        Err(anyhow!(
            "no maj{m}_{kind} artifact for {cols} columns — rebuild with \
             `make artifacts` (use --full for 65,536 columns)"
        ))
    }

    /// Common literal prologue shared by step and ECR graphs.
    fn lattice_args(&self, calib: &Calibration) -> Result<Vec<xla::Literal>> {
        let lat = &calib.lattice;
        Ok(vec![
            buffers::i32_vec(&calib.levels.iter().map(|&v| v as i32).collect::<Vec<_>>()),
            buffers::f32_array(&lat.bits_table_f32(), &[8, 3])?,
            buffers::f32_vec(&lat.config.fracs.map(|x| x as f32)),
            buffers::f32_scalar(self.cfg.frac_r as f32),
        ])
    }

    /// Algorithm 1 on the PJRT path.
    pub fn calibrate(
        &self,
        bank: &ColumnBank,
        fc: &FracConfig,
        params: &CalibParams,
    ) -> Result<Calibration> {
        let cols = bank.cols();
        let lattice = OffsetLattice::build(&self.cfg, fc);
        let mut calib = Calibration::uniform(lattice, cols);
        if fc.kind == ConfigKind::Baseline {
            return Ok(calib);
        }
        let exe = self.find(5, "step", cols)?;
        anyhow::ensure!(
            exe.meta_usize("samples") == Some(params.samples as usize)
                || exe.meta_usize("samples").is_some(),
            "step artifact missing sample metadata"
        );
        let thr = bank.thresholds(&self.cfg);
        let thr_lit = buffers::f32_vec(&thr);
        for iter in 0..params.iterations {
            let seed = derive_seed(params.seed, &[bank.seed, iter as u64]) as u32;
            let mut args = vec![buffers::u32_scalar(seed)];
            args.extend(self.lattice_args(&calib)?);
            args.push(buffers::f32_scalar(const_q(5) as f32));
            args.push(thr_lit.clone());
            args.push(buffers::f32_scalar(self.cfg.sigma_noise as f32));
            args.push(buffers::f32_scalar(params.tau as f32));
            args.push(buffers::f32_scalar(1.0)); // update
            let out = self.metrics.time("pjrt.step", || exe.run(&args))?;
            self.metrics.incr("pjrt.step.calls");
            let new_levels = buffers::to_i32_vec(&out[0])?;
            for (lv, nl) in calib.levels.iter_mut().zip(&new_levels) {
                *lv = *nl as u8;
            }
        }
        Ok(calib)
    }

    /// Mass ECR measurement (the paper's 8,192 random inputs) in one
    /// executable call.
    pub fn measure_ecr(
        &self,
        bank: &ColumnBank,
        calib: &Calibration,
        m: usize,
        seed: u64,
    ) -> Result<EcrReport> {
        let cols = bank.cols();
        let exe = self.find(m, "ecr", cols)?;
        let total = exe
            .meta_usize("total_samples")
            .ok_or_else(|| anyhow!("ecr artifact missing total_samples"))?;
        let thr = bank.thresholds(&self.cfg);
        let seed32 = derive_seed(seed, &[bank.seed, m as u64]) as u32;
        let mut args = vec![buffers::u32_scalar(seed32)];
        args.extend(self.lattice_args(calib)?);
        args.push(buffers::f32_scalar(const_q(m) as f32));
        args.push(buffers::f32_vec(&thr));
        args.push(buffers::f32_scalar(self.cfg.sigma_noise as f32));
        let out = self.metrics.time("pjrt.ecr", || exe.run(&args))?;
        self.metrics.incr("pjrt.ecr.calls");
        let err = buffers::to_i32_vec(&out[0])?;
        Ok(EcrReport::from_error_counts(
            err.into_iter().map(|e| e.max(0) as u32).collect(),
            total as u32,
        ))
    }

    /// Fold the batch's per-bank seeds into one stream selector for a
    /// fused call (each bank's columns occupy distinct positions of the
    /// stacked vector, so per-column streams stay distinct).
    fn fold_bank_seeds(seeds: impl Iterator<Item = u64>) -> u64 {
        seeds.fold(0u64, |acc, s| derive_seed(acc, &[s]))
    }

    /// Fused Algorithm 1: stack every request's thresholds into one
    /// wide virtual bank and run the whole batch as one executable
    /// call per iteration. Returns `None` when the batch is not
    /// fusable (mixed configs/params, or no artifact matches the
    /// stacked width).
    fn try_calibrate_fused(&self, reqs: &[CalibRequest]) -> Result<Option<Vec<Calibration>>> {
        let first = &reqs[0];
        if reqs.len() < 2
            || first.config.kind == ConfigKind::Baseline
            || !reqs.iter().all(|r| r.config == first.config && r.params == first.params)
        {
            return Ok(None);
        }
        let total: usize = reqs.iter().map(|r| r.bank.cols()).sum();
        let Ok(exe) = self.find(5, "step", total) else {
            // Fusable batch, but no artifact for the stacked width —
            // the miss the `pjrt.batch.unfused` metric tracks.
            self.metrics.incr("pjrt.batch.unfused");
            return Ok(None);
        };
        let params = &first.params;
        let lattice = OffsetLattice::build(&self.cfg, &first.config);
        let mut fused = Calibration::uniform(lattice, total);
        let mut thr = Vec::with_capacity(total);
        for r in reqs {
            thr.extend(r.bank.thresholds(&self.cfg));
        }
        let thr_lit = buffers::f32_vec(&thr);
        let folded = Self::fold_bank_seeds(reqs.iter().map(|r| r.bank.seed));
        for iter in 0..params.iterations {
            let seed = derive_seed(params.seed, &[folded, iter as u64]) as u32;
            let mut args = vec![buffers::u32_scalar(seed)];
            args.extend(self.lattice_args(&fused)?);
            args.push(buffers::f32_scalar(const_q(5) as f32));
            args.push(thr_lit.clone());
            args.push(buffers::f32_scalar(self.cfg.sigma_noise as f32));
            args.push(buffers::f32_scalar(params.tau as f32));
            args.push(buffers::f32_scalar(1.0)); // update
            let out = self.metrics.time("pjrt.step", || exe.run(&args))?;
            self.metrics.incr("pjrt.step.calls");
            self.metrics.add("pjrt.step.banks_fused", reqs.len() as u64);
            let new_levels = buffers::to_i32_vec(&out[0])?;
            for (lv, nl) in fused.levels.iter_mut().zip(&new_levels) {
                *lv = *nl as u8;
            }
        }
        Ok(Some(split_levels(&fused, reqs.iter().map(|r| r.bank.cols()))))
    }

    /// Fused ECR battery for one group of requests sharing (m, config,
    /// seed tag): one executable call for all banks. `None` when no
    /// artifact matches the stacked width.
    fn try_measure_ecr_fused(
        &self,
        reqs: &[EcrRequest],
        group: &[usize],
    ) -> Result<Option<Vec<EcrReport>>> {
        let total: usize = group.iter().map(|&i| reqs[i].bank.cols()).sum();
        let first = &reqs[group[0]];
        let Ok(exe) = self.find(first.m, "ecr", total) else {
            // Fusable group, but no artifact for the stacked width.
            self.metrics.incr("pjrt.batch.unfused");
            return Ok(None);
        };
        let total_samples = exe
            .meta_usize("total_samples")
            .ok_or_else(|| anyhow!("ecr artifact missing total_samples"))?;
        let mut fused = Calibration {
            lattice: first.calib.lattice.clone(),
            levels: Vec::with_capacity(total),
        };
        let mut thr = Vec::with_capacity(total);
        for &i in group {
            let r = &reqs[i];
            debug_assert_eq!(r.calib.cols(), r.bank.cols());
            fused.levels.extend_from_slice(&r.calib.levels);
            thr.extend(r.bank.thresholds(&self.cfg));
        }
        let folded = Self::fold_bank_seeds(group.iter().map(|&i| reqs[i].bank.seed));
        let seed32 = derive_seed(first.seed, &[folded, first.m as u64]) as u32;
        let mut args = vec![buffers::u32_scalar(seed32)];
        args.extend(self.lattice_args(&fused)?);
        args.push(buffers::f32_scalar(const_q(first.m) as f32));
        args.push(buffers::f32_vec(&thr));
        args.push(buffers::f32_scalar(self.cfg.sigma_noise as f32));
        let out = self.metrics.time("pjrt.ecr", || exe.run(&args))?;
        self.metrics.incr("pjrt.ecr.calls");
        self.metrics.add("pjrt.ecr.banks_fused", group.len() as u64);
        let err = buffers::to_i32_vec(&out[0])?;
        let counts: Vec<u32> = err.into_iter().map(|e| e.max(0) as u32).collect();
        let mut reports = Vec::with_capacity(group.len());
        let mut off = 0;
        for &i in group {
            let cols = reqs[i].bank.cols();
            reports.push(EcrReport::from_error_counts(
                counts[off..off + cols].to_vec(),
                total_samples as u32,
            ));
            off += cols;
        }
        Ok(Some(reports))
    }
}

/// Split a fused (stacked) calibration back into per-bank calibrations.
fn split_levels(fused: &Calibration, widths: impl Iterator<Item = usize>) -> Vec<Calibration> {
    let mut out = Vec::new();
    let mut off = 0;
    for cols in widths {
        out.push(Calibration {
            lattice: fused.lattice.clone(),
            levels: fused.levels[off..off + cols].to_vec(),
        });
        off += cols;
    }
    debug_assert_eq!(off, fused.cols());
    out
}

impl CalibEngine for PjrtEngine {
    fn backend(&self) -> &'static str {
        "pjrt"
    }

    fn calibrate_batch(&self, reqs: &[CalibRequest]) -> Result<Vec<Calibration>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        if let Some(fused) = self.try_calibrate_fused(reqs)? {
            return Ok(fused);
        }
        reqs.iter()
            .map(|r| self.calibrate(&r.bank, &r.config, &r.params))
            .collect()
    }

    fn measure_ecr_batch(&self, reqs: &[EcrRequest]) -> Result<Vec<EcrReport>> {
        let mut out: Vec<Option<EcrReport>> = (0..reqs.len()).map(|_| None).collect();
        let mut grouped = vec![false; reqs.len()];
        for i in 0..reqs.len() {
            if grouped[i] {
                continue;
            }
            grouped[i] = true;
            // Requests fuse when they share the operand count, the
            // lattice configuration and the stream-domain tag.
            let mut group = vec![i];
            for j in i + 1..reqs.len() {
                if !grouped[j]
                    && reqs[j].m == reqs[i].m
                    && reqs[j].seed == reqs[i].seed
                    && reqs[j].calib.lattice.config == reqs[i].calib.lattice.config
                {
                    grouped[j] = true;
                    group.push(j);
                }
            }
            let fused = if group.len() >= 2 {
                self.try_measure_ecr_fused(reqs, &group)?
            } else {
                None
            };
            match fused {
                Some(reports) => {
                    for (&k, rep) in group.iter().zip(reports) {
                        out[k] = Some(rep);
                    }
                }
                None => {
                    for &k in &group {
                        let r = &reqs[k];
                        out[k] = Some(self.measure_ecr(&r.bank, &r.calib, r.m, r.seed)?);
                    }
                }
            }
        }
        Ok(out.into_iter().map(|o| o.expect("all requests answered")).collect())
    }
}

/// Classify a lowering against the fused per-step execution
/// vocabulary: the number of steps with **no** batched lowering, which
/// would fall back to bank-serial execution. Input/NOT/readout steps
/// are column-interface traffic, releases are bookkeeping, and
/// MAJ3/MAJ5 are the two SiMRA arities the kernel vocabulary
/// implements — so every built-in [`crate::pud::plan::PudOp`] lowers
/// with zero unfusable steps; only an exotic hand-built gate arity
/// falls outside the vocabulary.
pub fn unfusable_steps(lowered: &LoweredPlan) -> usize {
    lowered
        .steps
        .iter()
        .filter(|s| match s {
            LoweredStep::Majx { m, .. } => *m != 3 && *m != 5,
            _ => false,
        })
        .count()
}

/// Arithmetic serving on the PJRT backend: requests run through the
/// same grouped, batch-fused lowered-step dispatch as the native
/// engine, on one lazily-built fallback engine held for the engine's
/// lifetime ([`PjrtEngine::fallback_engine`]) — not one constructed
/// per call. `pjrt.compute.fallback` counts **per-step** fallbacks
/// ([`unfusable_steps`], step classes outside the fused vocabulary),
/// not whole batches: a built-in-vocabulary serve reports zero
/// fallbacks, the way fully-stacked calibration batches report zero
/// `pjrt.batch.unfused`. Compute wall-clock is timed under
/// `pjrt.compute`.
impl ComputeEngine for PjrtEngine {
    fn compute_backend(&self) -> &'static str {
        "pjrt-native-fallback"
    }

    fn execute_batch(&self, reqs: &[ComputeRequest]) -> Result<Vec<ComputeResult>> {
        for req in reqs {
            if let Ok(lowered) = req.plan.lowered() {
                self.metrics.add("pjrt.compute.fallback", unfusable_steps(&lowered) as u64);
            }
        }
        self.metrics.time("pjrt.compute", || self.fallback_engine().execute_batch(reqs))
    }
}

/// Per-bank measurement outcome (the unit Table I aggregates).
#[derive(Clone, Debug)]
pub struct BankOutcome {
    pub bank_seed: u64,
    /// MAJ5 ECR, baseline / PUDTune.
    pub ecr5_base: f64,
    pub ecr5_tune: f64,
    /// Arithmetic (MAJ5 ∧ MAJ3) ECR, baseline / PUDTune.
    pub ecr_arith_base: f64,
    pub ecr_arith_tune: f64,
}

/// Device-level coordinator over any [`CalibEngine`] backend.
///
/// Builds whole-device request batches and hands them to the engine in
/// one call per phase, so batching decisions (worker-pool fan-out on
/// the native engine, stacked-bank executable calls on PJRT) live with
/// the backend — coordination is backend-agnostic, and coordinating
/// the *native* engine is just `DeviceCoordinator::new(cfg, sys,
/// NativeEngine::new(cfg))`.
pub struct DeviceCoordinator<E> {
    pub cfg: DeviceConfig,
    pub sys: SystemConfig,
    pub engine: E,
}

impl<E: CalibEngine> DeviceCoordinator<E> {
    pub fn new(cfg: DeviceConfig, sys: SystemConfig, engine: E) -> Self {
        Self { cfg, sys, engine }
    }

    /// Calibrate + measure one bank under baseline and PUDTune configs.
    pub fn bank_outcome(
        &self,
        bank_seed: u64,
        base: &FracConfig,
        tune: &FracConfig,
        params: &CalibParams,
        ecr_samples: u32,
    ) -> Result<BankOutcome> {
        let batch = BankBatch::with_seeds(self.cfg.clone(), self.sys.cols, vec![bank_seed]);
        let mut outcomes = self.run_batch(&batch, base, tune, params, ecr_samples)?;
        Ok(outcomes.pop().expect("one bank in, one outcome out"))
    }

    /// All banks of the configured system, in two engine calls: one
    /// batched calibration, then one batched ECR phase covering every
    /// (bank, config, MAJ-m) combination.
    pub fn run_banks(
        &self,
        device_seed: u64,
        banks: usize,
        base: &FracConfig,
        tune: &FracConfig,
        params: &CalibParams,
        ecr_samples: u32,
    ) -> Result<Vec<BankOutcome>> {
        let batch =
            BankBatch::from_device_seed(self.cfg.clone(), self.sys.cols, device_seed, banks);
        self.run_batch(&batch, base, tune, params, ecr_samples)
    }

    /// Calibrate + measure an explicit bank batch.
    pub fn run_batch(
        &self,
        batch: &BankBatch,
        base: &FracConfig,
        tune: &FracConfig,
        params: &CalibParams,
        ecr_samples: u32,
    ) -> Result<Vec<BankOutcome>> {
        let n = batch.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        // Materialise the variation fields once; every request below
        // snapshots from this one set of banks.
        let banks = batch.banks();
        let tuned = self
            .engine
            .calibrate_batch(&BankBatch::calib_requests_for(&banks, *tune, *params))?;
        let base_cal = base.uncalibrated(&self.cfg, batch.cols);
        // One ECR phase: (base, tune) x (MAJ5, MAJ3) for every bank —
        // 4N requests the engine may fuse into as few as 4 calls.
        let mut reqs = Vec::with_capacity(4 * n);
        for (m, seed) in [(5usize, ECR_SEED_MAJ5), (3usize, ECR_SEED_ARITH)] {
            for bank in &banks {
                reqs.push(
                    EcrRequest::new(bank.clone(), base_cal.clone(), m, ecr_samples)
                        .with_seed(seed),
                );
            }
            for (bank, cal) in banks.iter().zip(&tuned) {
                reqs.push(
                    EcrRequest::new(bank.clone(), cal.clone(), m, ecr_samples).with_seed(seed),
                );
            }
        }
        let reports = self.engine.measure_ecr_batch(&reqs)?;
        let (e5b, e5t) = (&reports[..n], &reports[n..2 * n]);
        let (e3b, e3t) = (&reports[2 * n..3 * n], &reports[3 * n..4 * n]);
        Ok((0..n)
            .map(|i| BankOutcome {
                bank_seed: batch.seeds[i],
                ecr5_base: e5b[i].ecr(),
                ecr5_tune: e5t[i].ecr(),
                ecr_arith_base: e5b[i].intersect(&e3b[i]).ecr(),
                ecr_arith_tune: e5t[i].intersect(&e3t[i]).ecr(),
            })
            .collect())
    }
}

/// Mean ECRs across a device's bank outcomes — the aggregate Table I
/// reports per configuration pair.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BankSummary {
    /// Number of banks aggregated.
    pub banks: usize,
    /// Mean MAJ5 ECR, baseline configuration.
    pub ecr5_base: f64,
    /// Mean MAJ5 ECR, PUDTune configuration.
    pub ecr5_tune: f64,
    /// Mean arithmetic (MAJ5 ∧ MAJ3) ECR, baseline.
    pub ecr_arith_base: f64,
    /// Mean arithmetic (MAJ5 ∧ MAJ3) ECR, PUDTune.
    pub ecr_arith_tune: f64,
}

impl BankSummary {
    pub fn from_outcomes(outcomes: &[BankOutcome]) -> Self {
        let n = outcomes.len().max(1) as f64;
        Self {
            banks: outcomes.len(),
            ecr5_base: outcomes.iter().map(|o| o.ecr5_base).sum::<f64>() / n,
            ecr5_tune: outcomes.iter().map(|o| o.ecr5_tune).sum::<f64>() / n,
            ecr_arith_base: outcomes.iter().map(|o| o.ecr_arith_base).sum::<f64>() / n,
            ecr_arith_tune: outcomes.iter().map(|o| o.ecr_arith_tune).sum::<f64>() / n,
        }
    }
}

impl fmt::Display for BankSummary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} banks: MAJ5 ECR {:.2}% -> {:.2}%, arith ECR {:.2}% -> {:.2}%",
            self.banks,
            self.ecr5_base * 100.0,
            self.ecr5_tune * 100.0,
            self.ecr_arith_base * 100.0,
            self.ecr_arith_tune * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_bank_matches_subarray_variation() {
        use crate::dram::subarray::Subarray;
        let cfg = DeviceConfig::default();
        let bank = ColumnBank::new(&cfg, 256, 99);
        let sub = Subarray::with_geometry(&cfg, 32, 256, 99);
        assert_eq!(bank.sa.variation.sa_offset, sub.sa.variation.sa_offset);
        assert_eq!(bank.thresholds(&cfg), sub.sa.effective_thresholds(&cfg, &sub.env));
    }

    #[test]
    fn bank_summary_aggregation_and_display() {
        let o = |b: f64, t: f64| BankOutcome {
            bank_seed: 0,
            ecr5_base: b,
            ecr5_tune: t,
            ecr_arith_base: b,
            ecr_arith_tune: t,
        };
        let s = BankSummary::from_outcomes(&[o(0.4, 0.04), o(0.6, 0.02)]);
        assert_eq!(s.banks, 2);
        assert!((s.ecr5_base - 0.5).abs() < 1e-12);
        assert!((s.ecr5_tune - 0.03).abs() < 1e-12);
        assert_eq!(s.ecr_arith_base, s.ecr5_base);
        assert_eq!(s.ecr_arith_tune, s.ecr5_tune);
        let text = s.to_string();
        assert!(text.contains("2 banks"), "{text}");
        assert!(text.contains("50.00% -> 3.00%"), "{text}");
    }

    #[test]
    fn column_bank_snapshot_tracks_environment() {
        use crate::config::system::SystemConfig;
        let cfg = DeviceConfig::default();
        let mut sys = SystemConfig::small();
        sys.cols = 128;
        let mut sub = crate::dram::subarray::Subarray::new(&cfg, &sys, 5);
        sub.set_temperature(88.0);
        let bank = ColumnBank::from_subarray(&sub, 5);
        assert_eq!(bank.env, sub.env);
        assert_eq!(bank.thresholds(&cfg), sub.sa.effective_thresholds(&cfg, &sub.env));
        assert_eq!(bank.cols(), 128);
    }
}
