//! L3 coordinator: bank-parallel experiment orchestration over the
//! PJRT runtime.
//!
//! The paper calibrates every subarray of every bank (§III-A) and
//! measures ECR per bank over 8,192 random inputs (§IV-A); on their
//! testbed the FPGA host does the bulk sampling. Here the coordinator
//! plays that role: it owns the device state, schedules per-subarray
//! calibration/measurement jobs onto a worker pool, batches them into
//! the AOT-compiled sampling executables, and aggregates the results
//! the analysis layer turns into Table I / Figs. 5-6.
//!
//! The layer stack mirrors the plan → engine → serve split documented
//! in [`crate::pud`]: plans and calibrations are compiled/identified
//! once, the engine traits ([`crate::calib::engine::CalibEngine`] and
//! [`crate::calib::engine::ComputeEngine`]) execute request batches on
//! a backend, and the service here owns the serving lifecycle on top.
//!
//! * [`engine`] — PJRT-backed calibration + ECR engine (one Algorithm-1
//!   iteration per executable call, multi-bank batches fused into one
//!   call) and the device-level coordinator, generic over any
//!   [`crate::calib::engine::CalibEngine`] backend; also the PJRT
//!   `ComputeEngine` fallback (per-bank native execution until
//!   circuit-execution artifacts exist);
//! * [`service`] — the drift-aware recalibration service: rehydrates
//!   calibrations from the non-volatile store, spot-checks them,
//!   serves measurement batteries *and arithmetic workloads*
//!   (`serve_workload`: current calibration + error-free column mask,
//!   golden-model-checked outputs), and schedules background
//!   recalibration when drift signals fire (the persist → load →
//!   validate → recalibrate lifecycle);
//! * [`worker`] — std::thread scoped worker pool (`parallel_map` /
//!   panic-contained `try_parallel_map`);
//! * [`batcher`] — generic micro-batching queue (used by the e2e GEMV
//!   serving example);
//! * [`metrics`] — counters/timers reported by the CLI and benches.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod service;
pub mod worker;
