//! L3 coordinator: bank-parallel experiment orchestration over the
//! PJRT runtime.
//!
//! The paper calibrates every subarray of every bank (§III-A) and
//! measures ECR per bank over 8,192 random inputs (§IV-A); on their
//! testbed the FPGA host does the bulk sampling. Here the coordinator
//! plays that role: it owns the device state, schedules per-subarray
//! calibration/measurement jobs onto a worker pool, batches them into
//! the AOT-compiled sampling executables, and aggregates the results
//! the analysis layer turns into Table I / Figs. 5-6.
//!
//! The layer stack mirrors the plan → engine → serve split documented
//! in [`crate::pud`]: plans and calibrations are compiled/identified
//! once, the engine traits ([`crate::calib::engine::CalibEngine`] and
//! [`crate::calib::engine::ComputeEngine`]) execute request batches on
//! a backend, and the service here owns the serving lifecycle on top.
//!
//! * [`engine`] — PJRT-backed calibration + ECR engine (one Algorithm-1
//!   iteration per executable call, multi-bank batches fused into one
//!   call) and the device-level coordinator, generic over any
//!   [`crate::calib::engine::CalibEngine`] backend; also the PJRT
//!   `ComputeEngine` (per-lowered-step fallback accounting over one
//!   shared native fallback engine until circuit-execution artifacts
//!   exist);
//! * [`plancache`] — process-wide LRU cache of compiled plans + their
//!   canonical lowerings, keyed by (op, geometry); `serve_workload`
//!   and the CLI resolve plans through it (`plan.cache.*` metrics);
//! * [`service`] — the drift-aware recalibration **server**, built
//!   around the threaded serve → admit → shard → worker → drain
//!   lifecycle: any number of client threads serve measurement
//!   batteries *and arithmetic workloads* (`serve_workload` /
//!   `serve_plan`: current calibration + error-free column mask,
//!   golden-model-checked outputs) through admission control (bounded
//!   in-flight serves, typed `Overloaded`/`Draining` rejections)
//!   against per-channel entry shards, while a `ServiceServer`'s
//!   background threads rehydrate/spot-check stored calibrations,
//!   poll drift, scrub, and recalibrate — and a graceful `drain()`
//!   finishes in-flight work, persists the store and joins every
//!   worker;
//! * [`worker`] — std::thread scoped worker pool (`parallel_map` /
//!   panic-contained `try_parallel_map` / single-job `run_contained`,
//!   the containment the service's long-lived workers run jobs under);
//! * [`batcher`] — generic micro-batching queue (used by the e2e GEMV
//!   serving example);
//! * [`metrics`] — counters/timers reported by the CLI and benches
//!   (see its module docs for the full metric-name reference,
//!   including the `admission.*`, `serve.concurrent` and `drain.*`
//!   lifecycle metrics).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod plancache;
pub mod service;
pub mod worker;
