//! Process-wide compiled-plan cache: (op, geometry) → compiled
//! [`WorkloadPlan`] + canonical [`LoweredPlan`].
//!
//! Compiling a plan (circuit synthesis, liveness analysis, the static
//! charge-state verification self-check) and lowering it are pure
//! functions of the op — paying them once per *serve* is pure waste on
//! a hot serving path. [`PlanCache`] memoizes the pair behind an
//! `Arc`, keyed by the op plus an optional row-geometry pin (rows = 0
//! means geometry-agnostic; a nonzero row count additionally
//! pre-checks the plan's scratch peak against that geometry's data
//! region, so impossible plans are rejected at lookup time, before any
//! request is built). Entries are evicted least-recently-used beyond
//! the configured capacity.
//!
//! `RecalibService::serve_workload` and the CLI (`pudtune run` /
//! `serve` / `campaign`) resolve plans through the process-wide
//! [`PlanCache::global`] instance; lookups report `plan.cache.hit` /
//! `plan.cache.miss` / `plan.cache.evicted` into the caller's
//! [`Metrics`] (catalogued in [`crate::coordinator::metrics`]).
//!
//! Width-narrowed variants ([`WorkloadPlan::narrowed`]) live in the
//! same cache under an extended (op, geometry, range-class) key:
//! [`PlanCache::get_or_narrow`] resolves the narrowed plan for a
//! [`RangeClass`] (per-operand covering bit-lengths), so every serve
//! whose operands fit the same class shares one narrowed compile.

use crate::coordinator::metrics::Metrics;
use crate::dram::geometry::RowMap;
use crate::pud::plan::{PudError, PudOp, WorkloadPlan};
use crate::pud::ranges::RangeClass;
use crate::pud::verify::LoweredPlan;
use std::sync::{Arc, Mutex, OnceLock};

/// A compiled plan and its canonical lowering, shared via `Arc` by
/// every serve that resolves the same (op, geometry) key.
#[derive(Clone, Debug)]
pub struct CompiledPlan {
    /// The compiled, verifier-approved plan.
    pub plan: Arc<WorkloadPlan>,
    /// The plan's canonical lowering (the same `Arc` the plan itself
    /// caches, so engines never re-lower).
    pub lowered: Arc<LoweredPlan>,
}

/// Counters accumulated over a cache's lifetime ([`PlanCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that compiled (and inserted) a fresh plan.
    pub misses: u64,
    /// Entries evicted by the LRU capacity bound.
    pub evicted: u64,
}

struct Entry {
    op: PudOp,
    rows: usize,
    /// `None` for the full-width compile; `Some` for a width-narrowed
    /// variant keyed by its range class.
    class: Option<RangeClass>,
    compiled: Arc<CompiledPlan>,
    last_used: u64,
}

struct Inner {
    entries: Vec<Entry>,
    tick: u64,
    stats: CacheStats,
}

/// An LRU cache of compiled plans keyed by (op, rows). `PudOp` has no
/// `Hash`, and capacities are small (a serving vocabulary, not a
/// corpus), so lookups are a linear scan under one mutex.
pub struct PlanCache {
    inner: Mutex<Inner>,
    capacity: usize,
}

/// Capacity of the process-wide cache ([`PlanCache::global`]).
pub const GLOBAL_CAPACITY: usize = 128;

impl PlanCache {
    /// An empty cache holding at most `capacity` compiled plans
    /// (`capacity` is clamped to at least 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner {
                entries: Vec::new(),
                tick: 0,
                stats: CacheStats::default(),
            }),
            capacity: capacity.max(1),
        }
    }

    /// The process-wide cache the serving layer and CLI share.
    pub fn global() -> &'static PlanCache {
        static GLOBAL: OnceLock<PlanCache> = OnceLock::new();
        GLOBAL.get_or_init(|| PlanCache::new(GLOBAL_CAPACITY))
    }

    /// Resolve `(op, rows)` to its compiled plan + lowering, compiling
    /// on first use. `rows = 0` is the geometry-agnostic key; a
    /// nonzero `rows` additionally pre-checks the plan's scratch peak
    /// against that subarray geometry's data region and fails with
    /// [`PudError::RowBudgetExceeded`] when the plan cannot fit.
    /// Compile/lowering errors are returned and never cached. When
    /// `metrics` is given, the lookup reports `plan.cache.hit` /
    /// `plan.cache.miss` / `plan.cache.evicted`.
    pub fn get_or_compile(
        &self,
        op: &PudOp,
        rows: usize,
        metrics: Option<&Metrics>,
    ) -> Result<Arc<CompiledPlan>, PudError> {
        if let Some(hit) = self.lookup(op, rows, None, metrics) {
            return Ok(hit);
        }
        // Compile + lower outside the lock: concurrent misses on the
        // same key race, but the loser adopts the winner's entry below
        // so every caller still shares one `Arc`.
        let plan = WorkloadPlan::compile(op.clone())?;
        Self::check_geometry(&plan, rows)?;
        let lowered = plan.lowered()?;
        let compiled = Arc::new(CompiledPlan { plan: Arc::new(plan), lowered });
        Ok(self.insert(op, rows, None, compiled, metrics))
    }

    /// Resolve the width-narrowed variant of an already-compiled
    /// `base` plan for a [`RangeClass`], narrowing on first use. The
    /// cache key is (op, rows, class), so every request whose operands
    /// cover the same per-operand bit-lengths shares one narrowed
    /// compile; the narrowed plan is re-verified by
    /// [`WorkloadPlan::narrowed`] before it is cached. Geometry
    /// pre-checks and metrics behave as in
    /// [`PlanCache::get_or_compile`].
    pub fn get_or_narrow(
        &self,
        base: &WorkloadPlan,
        rows: usize,
        class: &RangeClass,
        metrics: Option<&Metrics>,
    ) -> Result<Arc<CompiledPlan>, PudError> {
        if let Some(hit) = self.lookup(&base.op, rows, Some(class), metrics) {
            return Ok(hit);
        }
        let plan = base.narrowed(&class.ranges())?;
        Self::check_geometry(&plan, rows)?;
        let lowered = plan.lowered()?;
        let compiled = Arc::new(CompiledPlan { plan: Arc::new(plan), lowered });
        Ok(self.insert(&base.op, rows, Some(class), compiled, metrics))
    }

    fn check_geometry(plan: &WorkloadPlan, rows: usize) -> Result<(), PudError> {
        if rows > 0 {
            if rows < 32 {
                // `RowMap::standard` needs the reserved-row layout.
                return Err(PudError::RowBudgetExceeded { needed: 32, available: rows });
            }
            let available = rows.saturating_sub(RowMap::standard(rows).data_base);
            if available == 0 || plan.peak_rows > available {
                return Err(PudError::RowBudgetExceeded {
                    needed: plan.peak_rows.max(1),
                    available,
                });
            }
        }
        Ok(())
    }

    fn lookup(
        &self,
        op: &PudOp,
        rows: usize,
        class: Option<&RangeClass>,
        metrics: Option<&Metrics>,
    ) -> Option<Arc<CompiledPlan>> {
        let mut inner = self.inner.lock().expect("plan cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        let e = inner
            .entries
            .iter_mut()
            .find(|e| e.rows == rows && e.class.as_ref() == class && e.op == *op)?;
        e.last_used = tick;
        let compiled = e.compiled.clone();
        inner.stats.hits += 1;
        if let Some(m) = metrics {
            m.incr("plan.cache.hit");
        }
        Some(compiled)
    }

    fn insert(
        &self,
        op: &PudOp,
        rows: usize,
        class: Option<&RangeClass>,
        compiled: Arc<CompiledPlan>,
        metrics: Option<&Metrics>,
    ) -> Arc<CompiledPlan> {
        let mut inner = self.inner.lock().expect("plan cache poisoned");
        inner.tick += 1;
        let tick = inner.tick;
        inner.stats.misses += 1;
        if let Some(m) = metrics {
            m.incr("plan.cache.miss");
        }
        if let Some(e) = inner
            .entries
            .iter_mut()
            .find(|e| e.rows == rows && e.class.as_ref() == class && e.op == *op)
        {
            e.last_used = tick;
            return e.compiled.clone();
        }
        inner.entries.push(Entry {
            op: op.clone(),
            rows,
            class: class.cloned(),
            compiled: compiled.clone(),
            last_used: tick,
        });
        while inner.entries.len() > self.capacity {
            let idx = inner
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i)
                .expect("an overfull cache is nonempty");
            inner.entries.remove(idx);
            inner.stats.evicted += 1;
            if let Some(m) = metrics {
                m.incr("plan.cache.evicted");
            }
        }
        compiled
    }

    /// Lifetime hit/miss/eviction counters.
    pub fn stats(&self) -> CacheStats {
        self.inner.lock().expect("plan cache poisoned").stats
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("plan cache poisoned").entries.len()
    }

    /// Whether the cache holds no plans.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_share_one_arc_and_count() {
        let cache = PlanCache::new(4);
        let op = PudOp::Add { width: 2 };
        let a = cache.get_or_compile(&op, 0, None).unwrap();
        let b = cache.get_or_compile(&op, 0, None).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached Arc");
        assert!(Arc::ptr_eq(&a.lowered, &b.lowered));
        assert_eq!(cache.stats(), CacheStats { hits: 1, misses: 1, evicted: 0 });
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn geometry_is_part_of_the_key() {
        let cache = PlanCache::new(4);
        let op = PudOp::Add { width: 2 };
        let generic = cache.get_or_compile(&op, 0, None).unwrap();
        let pinned = cache.get_or_compile(&op, 96, None).unwrap();
        assert!(!Arc::ptr_eq(&generic, &pinned), "distinct geometry keys");
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 2, evicted: 0 });
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn impossible_geometry_is_rejected_not_cached() {
        let cache = PlanCache::new(4);
        let op = PudOp::Mul { width: 4 };
        let err = cache.get_or_compile(&op, 16, None).unwrap_err();
        assert!(matches!(err, PudError::RowBudgetExceeded { .. }), "{err:?}");
        assert!(cache.is_empty(), "errors must not be cached");
        // The same op still compiles under a workable geometry.
        cache.get_or_compile(&op, 96, None).unwrap();
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn malformed_ops_error_through() {
        let cache = PlanCache::new(4);
        let err = cache.get_or_compile(&PudOp::Add { width: 0 }, 0, None).unwrap_err();
        assert!(matches!(err, PudError::MalformedCircuit(_)), "{err:?}");
        assert!(cache.is_empty());
    }

    #[test]
    fn narrowed_variants_key_on_the_range_class() {
        use crate::pud::ranges::OperandRange;
        let cache = PlanCache::new(8);
        let op = PudOp::Add { width: 8 };
        let base = cache.get_or_compile(&op, 0, None).unwrap();
        let class = RangeClass::of(&[OperandRange::new(0, 15); 2]);
        let narrow = cache.get_or_narrow(&base.plan, 0, &class, None).unwrap();
        assert!(
            narrow.plan.circuit.gates.len() < base.plan.circuit.gates.len(),
            "narrowed variant must strip gates"
        );
        assert!(narrow.plan.is_verified(), "narrowed plans are re-verified");
        // Same class → the cached Arc; the full-width entry is untouched.
        let again = cache.get_or_narrow(&base.plan, 0, &class, None).unwrap();
        assert!(Arc::ptr_eq(&narrow, &again));
        let full = cache.get_or_compile(&op, 0, None).unwrap();
        assert!(Arc::ptr_eq(&base, &full));
        // A different class is a distinct entry.
        let wider = RangeClass::of(&[OperandRange::new(0, 63); 2]);
        let other = cache.get_or_narrow(&base.plan, 0, &wider, None).unwrap();
        assert!(!Arc::ptr_eq(&narrow, &other));
        assert_eq!(cache.len(), 3);
    }
}
