//! `pudtune` — CLI over every experiment in the paper.
//!
//! ```text
//! pudtune table1   [--banks N] [--cols N] [--native] [--samples N]
//! pudtune fig3
//! pudtune fig5     [--cols N] [--samples N]
//! pudtune fig6a    [--cols N]
//! pudtune fig6b    [--cols N]
//! pudtune ecr      [--fracs x,y,z] [--baseline x] [--cols N]
//! pudtune run      [--op add8,mul8|and|or|not|maj3|maj5] [--cols N]
//!                  [--rows N] [--samples N] [--fracs x,y,z] [--native]
//! pudtune calibrate [--cols N] [--store path] [--timed]
//! pudtune serve    [--banks N] [--cols N] [--ticks N] [--store path]
//!                  [--tick-hours H] [--excursion-temp C] [--excursion-tick K]
//!                  [--drift-temp dC] [--drift-age H] [--drift-ecr F] [--native]
//!                  [--workers N] [--burst N] [--env-match-temp dC]
//!                  [--env-match-hours H]
//! pudtune campaign [--banks N] [--cols N] [--epochs N] [--op add2]
//!                  [--redundancy N] [--native]
//! pudtune lint     [--max-width N] [--ranges] [--deny-warnings] [--json]
//!                  [circuit.pud ...]
//! pudtune analyze  [--op add8,mul8] [--max-width N] [--ranges=lo:hi,...]
//!                  [--check N] [--json]
//! pudtune fit-model [--target 0.466]
//! pudtune trace    [maj5|maj3] [--fracs x,y,z]
//! pudtune artifacts
//! pudtune cross-check [--cols N]
//! ```
//!
//! `--config file` overlays a `[device]/[system]/[experiment]` config
//! file (see `config::parse`) on the defaults.

use anyhow::{anyhow, Result};
use std::sync::Arc;

use pudtune::analysis::report;
use pudtune::calib::algorithm::CalibParams;
use pudtune::calib::engine::{AnyEngine, BankBatch, CalibEngine, CalibRequest, EcrRequest};
use pudtune::calib::lattice::FracConfig;
use pudtune::calib::store::CalibStore;
use pudtune::calib::sweep;
use pudtune::cli;
use pudtune::config::experiment::ExperimentConfig;
use pudtune::config::parse as cfgparse;
use pudtune::config::{device::DeviceConfig, system::SystemConfig};
use pudtune::controller::bender::BenderProgram;
use pudtune::dram::geometry::{RowMap, SubarrayId};
use pudtune::dram::subarray::Subarray;
use pudtune::experiments;
use pudtune::runtime::Runtime;
use pudtune::util::table;

const BOOL_FLAGS: &[&str] =
    &["native", "timed", "full", "help", "json", "ranges", "deny-warnings"];

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_configs(args: &cli::Args) -> Result<(DeviceConfig, SystemConfig, ExperimentConfig)> {
    let mut r = cfgparse::Resolved::default();
    if let Some(path) = args.str("config") {
        let text = std::fs::read_to_string(path)?;
        let cf = cfgparse::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        r = cfgparse::resolve(&cf).map_err(|e| anyhow!("{path}: {e}"))?;
    }
    // CLI overrides.
    if args.flag("full") {
        r.system.cols = 65536;
    }
    r.system.cols = args.usize("cols", r.system.cols).map_err(anyhow::Error::msg)?;
    r.experiment.banks = args.usize("banks", r.experiment.banks).map_err(anyhow::Error::msg)?;
    r.experiment.ecr_samples =
        args.usize("samples", r.experiment.ecr_samples as usize).map_err(anyhow::Error::msg)? as u32;
    r.experiment.seed = args.u64("seed", r.experiment.seed).map_err(anyhow::Error::msg)?;
    Ok((r.device, r.system, r.experiment))
}

fn run(raw: &[String]) -> Result<()> {
    let args = cli::parse(raw, BOOL_FLAGS).map_err(anyhow::Error::msg)?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    if args.flag("help") {
        return help();
    }
    match sub.as_str() {
        "help" => help(),
        "table1" => cmd_table1(&args),
        "fig3" => cmd_fig3(&args),
        "fig5" => cmd_fig5(&args),
        "fig6a" => cmd_fig6(&args, true),
        "fig6b" => cmd_fig6(&args, false),
        "ecr" => cmd_ecr(&args),
        "run" => cmd_run(&args),
        "calibrate" => cmd_calibrate(&args),
        "serve" => cmd_serve(&args),
        "campaign" => cmd_campaign(&args),
        "lint" => cmd_lint(&args),
        "analyze" => cmd_analyze(&args),
        "fit-model" => cmd_fit_model(&args),
        "trace" => cmd_trace(&args),
        "artifacts" => cmd_artifacts(),
        "cross-check" => cmd_cross_check(&args),
        other => Err(anyhow!("unknown subcommand '{other}' (try `pudtune help`)")),
    }
}

fn help() -> Result<()> {
    let text = include_str!("main.rs")
        .lines()
        .skip(1)
        .take_while(|l| l.starts_with("//!"))
        .map(|l| l.trim_start_matches("//! ").trim_start_matches("//!"))
        .collect::<Vec<_>>()
        .join("\n");
    println!("{text}");
    Ok(())
}

/// The backend behind the `CalibEngine` trait: `--native` forces the
/// golden-model kernel, otherwise PJRT with native fallback.
fn engine_for(args: &cli::Args, cfg: &DeviceConfig) -> AnyEngine {
    if args.flag("native") {
        AnyEngine::native(cfg.clone())
    } else {
        AnyEngine::auto(cfg.clone())
    }
}

fn cmd_table1(args: &cli::Args) -> Result<()> {
    let (cfg, sys, exp) = load_configs(args)?;
    let base = FracConfig::baseline(3);
    let tune = FracConfig::pudtune(args.fracs("fracs", [2, 1, 0]).map_err(anyhow::Error::msg)?);
    let engine = engine_for(args, &cfg);
    let t0 = std::time::Instant::now();
    let r = experiments::run_table1(&cfg, &sys, &exp, &engine, base, tune)?;
    println!(
        "Table I — ECR and throughput ({} banks x {} cols, {} ECR samples)",
        exp.banks, sys.cols, exp.ecr_samples
    );
    println!("{}", r.rendered);
    println!(
        "capacity overhead: {:.1}% (3 calibration rows / {} rows per subarray)",
        100.0 * sys.calib_capacity_overhead(3),
        sys.rows_per_subarray
    );
    println!("elapsed: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_fig3(args: &cli::Args) -> Result<()> {
    let (cfg, _, _) = load_configs(args)?;
    println!("{}", experiments::run_fig3(&cfg));
    Ok(())
}

fn cmd_fig5(args: &cli::Args) -> Result<()> {
    let (cfg, sys, exp) = load_configs(args)?;
    let pts = experiments::run_fig5(&cfg, &sys, &exp);
    let rows: Vec<(FracConfig, f64, f64)> =
        pts.iter().map(|p| (p.config, p.ecr, p.maj5_ops)).collect();
    println!("Fig. 5 — MAJ5 sensitivity to Frac configuration\n");
    println!("{}", report::render_sweep(&rows));
    let chart: Vec<(String, f64)> = pts
        .iter()
        .map(|p| (p.config.label(), p.maj5_ops / 1e12))
        .collect();
    println!("{}", table::bar_chart("MAJ5 throughput (TOPS)", &chart, "TOPS", 40));
    Ok(())
}

fn cmd_fig6(args: &cli::Args, temp: bool) -> Result<()> {
    let (cfg, sys, exp) = load_configs(args)?;
    let (pts, axis, bound) = if temp {
        (experiments::run_fig6a(&cfg, &sys, &exp), "Temp (C)", 0.0014)
    } else {
        (experiments::run_fig6b(&cfg, &sys, &exp), "Hours", 0.0027)
    };
    println!(
        "Fig. 6{} — reliability (new error-prone columns vs calibration time; paper bound {:.2}%)\n",
        if temp { "a" } else { "b" },
        bound * 100.0
    );
    let series: Vec<(f64, f64)> = pts.iter().map(|p| (p.x, p.new_ecr)).collect();
    println!("{}", report::render_reliability(axis, &series));
    Ok(())
}

fn cmd_ecr(args: &cli::Args) -> Result<()> {
    let (cfg, sys, exp) = load_configs(args)?;
    let fc = if let Some(x) = args.str("baseline") {
        FracConfig::baseline(x.parse().map_err(|_| anyhow!("--baseline: bad integer"))?)
    } else {
        FracConfig::pudtune(args.fracs("fracs", [2, 1, 0]).map_err(anyhow::Error::msg)?)
    };
    let engine = AnyEngine::native(cfg.clone());
    let sub = Subarray::with_geometry(&cfg, 32, sys.cols, exp.seed);
    let params = CalibParams {
        iterations: exp.calib_iterations,
        samples: exp.calib_samples,
        tau: exp.bias_tau,
        seed: exp.seed,
    };
    let calib =
        engine.calibrate_one(&CalibRequest::from_subarray(&sub, exp.seed, fc, params))?;
    let rep5 = engine.measure_ecr_one(&EcrRequest::from_subarray(
        &sub,
        exp.seed,
        calib.clone(),
        5,
        exp.ecr_samples,
    ))?;
    let rep3 = engine.measure_ecr_one(&EcrRequest::from_subarray(
        &sub,
        exp.seed,
        calib,
        3,
        exp.ecr_samples,
    ))?;
    println!("config {}  cols {}  samples {}", fc.label(), sys.cols, exp.ecr_samples);
    println!(
        "MAJ5 ECR: {:.2}%  ({} error-prone columns)",
        rep5.ecr() * 100.0,
        rep5.error_prone()
    );
    println!("MAJ3 ECR: {:.2}%", rep3.ecr() * 100.0);
    println!(
        "arithmetic-usable columns: {:.2}%",
        (1.0 - rep5.intersect(&rep3).ecr()) * 100.0
    );
    Ok(())
}

/// Serve arithmetic workloads end to end through the batch-first
/// stack: calibrate via `CalibEngine`, derive conventional vs PUDTune
/// error-free column masks from arithmetic (MAJ5 ∧ MAJ3) batteries,
/// execute each op through `ComputeEngine`, check outputs against the
/// software golden model, and report Eq. 1 *effective* throughput for
/// both masks — the paper's Table-I add/mul uplift, reproduced on the
/// serving path.
fn cmd_run(args: &cli::Args) -> Result<()> {
    use pudtune::analysis::throughput::ThroughputModel;
    use pudtune::calib::engine::{measure_arith_batteries, ComputeEngine, ComputeRequest};
    use pudtune::coordinator::plancache::PlanCache;
    use pudtune::pud::plan::PudOp;
    use pudtune::util::rng::Rng;

    let (cfg, _, exp) = load_configs(args)?;
    let cols = args.usize("cols", 1024).map_err(anyhow::Error::msg)?;
    let rows = args.usize("rows", 192).map_err(anyhow::Error::msg)?;
    let mut op_names = args.list("op");
    if op_names.is_empty() {
        op_names = vec!["add8".into(), "mul8".into()];
    }
    let ops = op_names
        .iter()
        .map(|name| PudOp::parse_or_list(name).map_err(|e| anyhow!(e)))
        .collect::<Result<Vec<_>>>()?;

    let engine = engine_for(args, &cfg);
    let seed = exp.seed;
    let sub = Subarray::with_geometry(&cfg, rows, cols, seed);
    let tune = FracConfig::pudtune(args.fracs("fracs", [2, 1, 0]).map_err(anyhow::Error::msg)?);
    let base = FracConfig::baseline(3);
    let params = CalibParams {
        iterations: exp.calib_iterations,
        samples: exp.calib_samples,
        tau: exp.bias_tau,
        seed: exp.seed,
    };
    let t0 = std::time::Instant::now();
    let calib = engine.calibrate_one(&CalibRequest::from_subarray(&sub, seed, tune, params))?;
    let base_cal = base.uncalibrated(&cfg, cols);

    // Arithmetic-usable masks: a column serves a circuit only if both
    // its MAJ5 and MAJ3 are error-free (one batched ECR phase).
    let batteries =
        measure_arith_batteries(&engine, &sub, seed, &[&base_cal, &calib], exp.ecr_samples)?;
    let base_arith = batteries[0].arith();
    let tune_arith = batteries[1].arith();
    println!(
        "workload serving via ComputeEngine ({} backend), {cols} cols x {rows} rows:",
        engine.compute_backend()
    );
    println!(
        "  arithmetic-usable columns: conventional {} ({:.1}%), PUDTune {} ({:.1}%)",
        base_arith.error_free(),
        100.0 * (1.0 - base_arith.ecr()),
        tune_arith.error_free(),
        100.0 * (1.0 - tune_arith.ecr())
    );

    let tput = ThroughputModel::new(&SystemConfig::paper());
    let mut rng = Rng::new(seed ^ 0x50D);
    for op in ops {
        // Compiled-plan cache, pinned to this run's geometry: repeated
        // invocations of the same op pay compile + lower + verify once.
        let compiled =
            PlanCache::global().get_or_compile(&op, rows, None).map_err(|e| anyhow!("{e}"))?;
        let plan = compiled.plan.clone();
        let width = plan.op.operand_width();
        let operands: Vec<Vec<u64>> = (0..plan.op.n_operands())
            .map(|_| (0..cols).map(|_| rng.below(1u64 << width)).collect())
            .collect();
        println!("\n{} ({} MAJ3 + {} MAJ5 + {} NOT per column):",
            plan.op.label(), plan.cost.maj3, plan.cost.maj5, plan.cost.not_ops);
        let mut effective = Vec::with_capacity(2);
        for (label, fc, cal, battery) in [
            ("conventional", &base, &base_cal, &base_arith),
            ("PUDTune     ", &tune, &calib, &tune_arith),
        ] {
            let req = ComputeRequest::from_subarray(
                &sub,
                seed,
                plan.clone(),
                cal.clone(),
                operands.clone(),
            )
            .with_mask(battery.error_free_mask());
            let golden = req.golden_outputs().map_err(|e| anyhow!("{e}"))?;
            let res = engine.execute_one(&req)?;
            let correct = res.golden_correct(&golden);
            let free_frac = res.active_cols() as f64 / cols as f64;
            let ops_s = tput.workload_ops(&plan.cost, fc, free_frac);
            effective.push(ops_s);
            println!(
                "  {label}: {correct}/{} masked columns golden-correct, \
                 {:.1} us of DRAM commands, effective {}",
                res.active_cols(),
                res.elapsed_ns / 1000.0,
                table::fmt_ops(ops_s)
            );
        }
        println!(
            "  PUDTune uplift: {:.2}x effective {} throughput (paper: 1.88x ADD / 1.89x MUL)",
            effective[1] / effective[0],
            plan.op.label()
        );
    }
    let cs = PlanCache::global().stats();
    println!(
        "\nplan cache: {} hit(s), {} miss(es), {} evicted",
        cs.hits, cs.misses, cs.evicted
    );
    println!("elapsed: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_calibrate(args: &cli::Args) -> Result<()> {
    let (cfg, sys, exp) = load_configs(args)?;
    let fc = FracConfig::pudtune(args.fracs("fracs", [2, 1, 0]).map_err(anyhow::Error::msg)?);
    let params = CalibParams {
        iterations: exp.calib_iterations,
        samples: exp.calib_samples,
        tau: exp.bias_tau,
        seed: exp.seed,
    };
    let engine = AnyEngine::native(cfg.clone());
    let mut store = CalibStore::default();
    let t0 = std::time::Instant::now();
    // Whole-device batch: one calibration call and one ECR call; the
    // engine fans the banks across the worker pool.
    let ids: Vec<SubarrayId> = (0..exp.banks).map(|b| SubarrayId::new(0, b, 0)).collect();
    let seeds: Vec<u64> = ids
        .iter()
        .map(|id| pudtune::util::rng::derive_seed(exp.seed, &id.seed_path()))
        .collect();
    let batch = BankBatch::with_seeds(cfg.clone(), sys.cols, seeds);
    let banks = batch.banks();
    let calibs = engine.calibrate_batch(&BankBatch::calib_requests_for(&banks, fc, params))?;
    let reports = engine
        .measure_ecr_batch(&BankBatch::ecr_requests_for(&banks, &calibs, 5, exp.ecr_samples))?;
    for (b, ((id, calib), rep)) in ids.iter().zip(&calibs).zip(&reports).enumerate() {
        println!("bank {b}: ECR {:.2}% after calibration", rep.ecr() * 100.0);
        store.insert(*id, calib);
    }
    if args.flag("timed") {
        println!(
            "calibration wall-clock: {:.2}s for {} subarrays, batched ({:.2}s amortised each; paper: ~60s each on DRAM Bender)",
            t0.elapsed().as_secs_f64(),
            exp.banks,
            t0.elapsed().as_secs_f64() / exp.banks as f64
        );
    }
    if let Some(path) = args.str("store") {
        store.save_file(std::path::Path::new(path))?;
        println!("calibration store written to {path}");
    }
    Ok(())
}

/// The drift-aware serving loop: rehydrate from the store, spot-check,
/// serve ticks, watch drift signals, recalibrate in the background and
/// write the refreshed store back.
fn cmd_serve(args: &cli::Args) -> Result<()> {
    use pudtune::calib::drift::DriftPolicy;
    use pudtune::coordinator::service::{
        LoadOutcome, RecalibService, ServiceConfig, ServiceServer,
    };
    use pudtune::coordinator::worker;
    use pudtune::pud::plan::{PudError, PudOp};

    let (cfg, sys, exp) = load_configs(args)?;
    let mut policy = DriftPolicy::default();
    if let Some(v) = args.f64_opt("drift-temp").map_err(anyhow::Error::msg)? {
        policy.max_temp_delta_c = v;
    }
    if let Some(v) = args.f64_opt("drift-age").map_err(anyhow::Error::msg)? {
        policy.max_age_hours = v;
    }
    if let Some(v) = args.f64_opt("drift-ecr").map_err(anyhow::Error::msg)? {
        policy.max_serve_ecr = v;
        policy.accept_max_ecr = v;
    }
    // Opt-in environment-match fast accept on rehydration (both axes
    // must be given for the fast path to engage).
    if let Some(v) = args.f64_opt("env-match-temp").map_err(anyhow::Error::msg)? {
        policy.env_match_temp_c = v;
    }
    if let Some(v) = args.f64_opt("env-match-hours").map_err(anyhow::Error::msg)? {
        policy.env_match_hours = v;
    }
    let ticks = args.usize("ticks", 6).map_err(anyhow::Error::msg)?;
    let tick_hours = args.f64("tick-hours", 1.0).map_err(anyhow::Error::msg)?;
    let excursion_temp = args.f64_opt("excursion-temp").map_err(anyhow::Error::msg)?;
    let excursion_tick = args.usize("excursion-tick", 3).map_err(anyhow::Error::msg)?;
    let workers = args
        .usize("workers", worker::default_threads())
        .map_err(anyhow::Error::msg)?;
    let burst = args.usize("burst", 4).map_err(anyhow::Error::msg)?;
    let svc = ServiceConfig {
        policy,
        serve_samples: exp.ecr_samples,
        params: CalibParams {
            iterations: exp.calib_iterations,
            samples: exp.calib_samples,
            tau: exp.bias_tau,
            seed: exp.seed,
        },
        ..ServiceConfig::default()
    };
    let engine = engine_for(args, &cfg);
    let service =
        Arc::new(RecalibService::new(cfg.clone(), svc, engine).map_err(anyhow::Error::msg)?);
    for b in 0..exp.banks {
        service.register(SubarrayId::new(0, b, 0), 32, sys.cols, exp.seed);
    }

    // Rehydrate from the non-volatile store, if one is given — before
    // the background workers start, so the cold-start queue is already
    // pruned to the entries the store could not satisfy.
    let store_path = args.str("store").map(std::path::PathBuf::from);
    if let Some(path) = &store_path {
        if path.exists() {
            let store = CalibStore::load_file(path).map_err(anyhow::Error::msg)?;
            println!("rehydrating {} banks from {}...", exp.banks, path.display());
            for (id, outcome) in service.load_store(&store) {
                match outcome {
                    LoadOutcome::Accepted { spot_ecr } => println!(
                        "  bank {}: accepted (spot ECR {:.2}%)",
                        id.bank,
                        spot_ecr * 100.0
                    ),
                    LoadOutcome::AcceptedOnEnv { temp_delta_c, hours_delta } => println!(
                        "  bank {}: accepted on env match (d{:.2} C, d{:.2} h), no spot check",
                        id.bank, temp_delta_c, hours_delta
                    ),
                    LoadOutcome::Rejected { spot_ecr } => println!(
                        "  bank {}: REJECTED (spot ECR {:.2}%), recalibrating",
                        id.bank,
                        spot_ecr * 100.0
                    ),
                    LoadOutcome::Missing => {
                        println!("  bank {}: no stored entry, calibrating", id.bank)
                    }
                    LoadOutcome::Incompatible(e) => {
                        println!("  bank {}: incompatible entry ({e}), recalibrating", id.bank)
                    }
                }
            }
        } else {
            println!("store {} not found; cold-starting", path.display());
        }
    }
    let fresh = service.run_pending(usize::MAX);
    if !fresh.is_empty() {
        println!("calibrated {} banks from scratch", fresh.len());
    }

    // The concurrent serving loop: background workers own drift polls,
    // scrubs and recalibration; this thread keeps serving batteries
    // and arithmetic bursts against them.
    println!("starting server: {workers} recalibration workers + maintenance ticker");
    let server = ServiceServer::start(service.clone(), workers);
    let compiled = pudtune::coordinator::plancache::PlanCache::global().get_or_compile(
        &PudOp::Add { width: 2 },
        0,
        Some(&*service.metrics),
    )?;
    let plan = compiled.plan.clone();
    let a: Vec<u64> = (0..sys.cols as u64).map(|c| c % 4).collect();
    let b: Vec<u64> = (0..sys.cols as u64).map(|c| (c * 5 + 2) % 4).collect();
    let operands = [a, b];
    for tick in 1..=ticks {
        if let (Some(temp), true) = (excursion_temp, tick == excursion_tick) {
            println!("\n-- tick {tick}: temperature excursion to {temp:.0} C --");
            for id in service.ids() {
                service.set_temperature(id, temp);
            }
        } else {
            println!("\n-- tick {tick} --");
        }
        let outcomes = service.serve();
        let mut ecrs = Vec::new();
        for o in &outcomes {
            match &o.report {
                Ok(rep) => ecrs.push(rep.ecr()),
                Err(e) => println!("  bank {} FAILED: {e}", o.id.bank),
            }
        }
        if !ecrs.is_empty() {
            let mean = ecrs.iter().sum::<f64>() / ecrs.len() as f64;
            println!(
                "  served {} banks, mean ECR {:.2}% (min {:.2}%, max {:.2}%)",
                ecrs.len(),
                mean * 100.0,
                ecrs.iter().cloned().fold(f64::INFINITY, f64::min) * 100.0,
                ecrs.iter().cloned().fold(0.0f64, f64::max) * 100.0
            );
        }
        // Arithmetic burst: admission-controlled, served under the
        // battery-refreshed masks while repairs run in the background.
        let (mut correct, mut active, mut rejected) = (0usize, 0usize, 0usize);
        for _ in 0..burst {
            match service.serve_plan(&plan, &operands) {
                Ok(outs) => {
                    for o in &outs {
                        correct += o.golden_correct;
                        active += o.active_cols;
                    }
                }
                Err(PudError::Overloaded { .. }) => rejected += 1,
                Err(e) => return Err(anyhow!("serve burst failed: {e}")),
            }
        }
        println!(
            "  burst: {burst} workloads, {correct}/{active} golden-correct columns\
             {}",
            if rejected > 0 { format!(", {rejected} rejected on backpressure") } else { String::new() }
        );
        if service.pending() > 0 {
            println!("  {} banks queued for background recalibration", service.pending());
        }
        service.advance_time(tick_hours);
    }

    // Graceful drain: background workers finish every queued repair,
    // then hand back the persistable store.
    let store = server.drain();
    println!(
        "\ndrained: {} entries persisted in {:.3}s",
        store.entries.len(),
        service.metrics.seconds("drain.seconds")
    );
    if let Some(path) = &store_path {
        store.save_file(path)?;
        println!("store written to {}", path.display());
    }
    let cs = pudtune::coordinator::plancache::PlanCache::global().stats();
    println!(
        "plan cache: {} hit(s), {} miss(es), {} evicted",
        cs.hits, cs.misses, cs.evicted
    );
    println!("\nservice metrics:\n{}", service.metrics.render());
    Ok(())
}

/// Fault-injection campaign: run the standard corruption campaign
/// (`dram::faults::standard_campaign`) against two serving stacks — an
/// unprotected baseline and a protected service with quarantine +
/// periodic scrub (plus optional redundant execution) — and report
/// per-epoch golden mismatches as the countermeasures converge.
fn cmd_campaign(args: &cli::Args) -> Result<()> {
    use pudtune::coordinator::service::{RecalibService, ServiceConfig, WorkloadOutcome};
    use pudtune::dram::faults::standard_campaign;
    use pudtune::pud::plan::PudOp;
    use pudtune::util::rng::Rng;

    /// Sum golden mismatches / served columns / bank failures over one
    /// epoch's outcomes.
    fn tally(outs: &[WorkloadOutcome]) -> (usize, usize, usize) {
        let mut bad = 0;
        let mut active = 0;
        let mut failures = 0;
        for o in outs {
            match &o.result {
                Ok(_) => {
                    bad += o.active_cols - o.golden_correct;
                    active += o.active_cols;
                }
                Err(_) => failures += 1,
            }
        }
        (bad, active, failures)
    }

    let (base_cfg, sys, exp) = load_configs(args)?;
    let cfg = standard_campaign(&base_cfg);
    let epochs = args.usize("epochs", 6).map_err(anyhow::Error::msg)?;
    let redundancy = args.usize("redundancy", 1).map_err(anyhow::Error::msg)?;
    let op_name = args.str("op").unwrap_or("add2");
    let op = PudOp::parse_or_list(op_name).map_err(|e| anyhow!(e))?;
    // Banks register with 32 rows below; pin the cached plan to that
    // geometry so impossible ops are rejected before any serving runs.
    let compiled = pudtune::coordinator::plancache::PlanCache::global()
        .get_or_compile(&op, 32, None)
        .map_err(|e| anyhow!("{e}"))?;
    let plan = compiled.plan.clone();
    let params = CalibParams {
        iterations: exp.calib_iterations,
        samples: exp.calib_samples,
        tau: exp.bias_tau,
        seed: exp.seed,
    };
    let protected_svc = ServiceConfig {
        serve_samples: exp.ecr_samples,
        params,
        quarantine_strikes: 2,
        quarantine_clean_passes: 2,
        scrub_every: 1,
        redundancy,
        ..ServiceConfig::default()
    };
    let baseline_svc = ServiceConfig {
        serve_samples: exp.ecr_samples,
        params,
        ..ServiceConfig::default()
    };
    let protected = RecalibService::new(cfg.clone(), protected_svc, engine_for(args, &cfg))
        .map_err(anyhow::Error::msg)?;
    let baseline = RecalibService::new(cfg.clone(), baseline_svc, engine_for(args, &cfg))
        .map_err(anyhow::Error::msg)?;
    for b in 0..exp.banks {
        let id = SubarrayId::new(0, b, 0);
        protected.register(id, 32, sys.cols, exp.seed);
        baseline.register(id, 32, sys.cols, exp.seed);
    }
    protected.run_pending(usize::MAX);
    baseline.run_pending(usize::MAX);

    // A fixed workload: identical (plan, operands, seed) every epoch,
    // so fault behaviour repeats and quarantine converges on the same
    // columns it observed mismatching.
    let mut rng = Rng::new(exp.seed ^ 0xCA4);
    let width = plan.op.operand_width();
    let operands: Vec<Vec<u64>> = (0..plan.op.n_operands())
        .map(|_| (0..sys.cols).map(|_| rng.below(1u64 << width)).collect())
        .collect();

    println!(
        "fault campaign: {} banks x {} cols, op {}, {} epochs, redundancy {}x",
        exp.banks,
        sys.cols,
        plan.op.label(),
        epochs,
        redundancy.max(1)
    );
    for epoch in 1..=epochs {
        let prot = protected.serve_plan(&plan, &operands).map_err(anyhow::Error::new)?;
        let base = baseline.serve_plan(&plan, &operands).map_err(anyhow::Error::new)?;
        let (p_bad, p_active, p_fail) = tally(&prot);
        let (b_bad, b_active, b_fail) = tally(&base);
        let quarantined: usize = protected
            .ids()
            .iter()
            .map(|id| protected.quarantine(*id).map_or(0, |q| q.quarantined_cols()))
            .sum();
        println!(
            "epoch {epoch}: unprotected {b_bad}/{b_active} mismatching, \
             protected {p_bad}/{p_active} mismatching, {quarantined} cols quarantined"
        );
        for (label, fails) in [("protected", p_fail), ("unprotected", b_fail)] {
            if fails > 0 {
                println!("  {fails} {label} bank(s) failed to serve");
            }
        }
        let (_, scrubs) = protected.maintain();
        for s in &scrubs {
            if let Err(e) = &s.result {
                println!("  scrub failed on bank {}: {e}", s.id.bank);
            }
        }
    }
    println!("\nprotected service metrics:\n{}", protected.metrics.render());
    Ok(())
}

/// Statically verify the entire built-in op vocabulary (arithmetic
/// widths up to `--max-width`) and any user-supplied circuit files
/// against the charge-state verifier. Error-severity diagnostics exit
/// nonzero; warnings are reported but tolerated unless
/// `--deny-warnings` promotes them. `--ranges` additionally runs the
/// bit-level range analysis (`pud::ranges`, full-width ranges) on
/// every target that compiles, folding its P009–P012 findings into the
/// same tally. `--json` renders one machine-readable report line per
/// target.
fn cmd_lint(args: &cli::Args) -> Result<()> {
    use pudtune::pud::plan::{PudOp, WorkloadPlan};
    use pudtune::pud::ranges::{analyze_plan, OperandRange};
    use pudtune::pud::verify::{self, Severity};

    let max_width = args.usize("max-width", 16).map_err(anyhow::Error::msg)?;
    let json = args.flag("json");
    let with_ranges = args.flag("ranges");
    let deny_warnings = args.flag("deny-warnings");
    let mut errors = 0usize;
    let mut warnings = 0usize;
    let mut targets = 0usize;

    // Returns this target's (error, warning) diagnostic counts.
    let report_one = |label: &str,
                      report: &verify::VerifyReport,
                      plan: Option<&WorkloadPlan>|
     -> (usize, usize) {
        let mut diags = report.diagnostics.clone();
        let mut range_part = String::new();
        if with_ranges {
            if let Some(plan) = plan {
                let full: Vec<OperandRange> = (0..plan.op.n_operands())
                    .map(|_| OperandRange::full(plan.op.operand_width()))
                    .collect();
                let rep = analyze_plan(plan, &full)
                    .expect("full-width ranges are always admissible");
                if json {
                    range_part = format!(",\"ranges\":{}", rep.to_json());
                }
                diags.extend(rep.diagnostics);
            }
        }
        let n_err = diags.iter().filter(|d| d.severity() == Severity::Error).count();
        if json {
            println!(
                "{{\"target\":\"{label}\",\"report\":{}{range_part}}}",
                report.to_json()
            );
        } else if diags.is_empty() {
            println!("{label}: clean (peak {} rows)", report.peak_rows);
        } else {
            println!("{label}: {} diagnostic(s), {n_err} error(s)", diags.len());
            for d in &diags {
                println!("  {d}");
            }
        }
        (n_err, diags.len() - n_err)
    };

    for op in PudOp::vocabulary(max_width) {
        let label = op.label();
        targets += 1;
        match WorkloadPlan::compile(op) {
            Ok(plan) => {
                let (e, w) = report_one(&label, &verify::verify_plan(&plan), Some(&plan));
                errors += e;
                warnings += w;
            }
            Err(e) => {
                errors += 1;
                println!("{label}: failed to compile: {e}");
            }
        }
    }
    for path in &args.positional {
        let text = std::fs::read_to_string(path)?;
        let circuit = verify::parse_circuit(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        targets += 1;
        let report = verify::verify_circuit(&circuit);
        let plan = WorkloadPlan::from_circuit(circuit).ok();
        let (e, w) = report_one(path, &report, plan.as_ref());
        errors += e;
        warnings += w;
    }
    if errors > 0 || (deny_warnings && warnings > 0) {
        return Err(anyhow!(
            "lint found {errors} error(s) and {warnings} warning(s) across {targets} target(s)"
        ));
    }
    if warnings > 0 {
        println!(
            "lint: {targets} target(s), {warnings} warning(s) tolerated \
             (use --deny-warnings to fail on them)"
        );
    } else {
        println!("lint: {targets} target(s) clean");
    }
    Ok(())
}

/// Bit-level range analysis (`pud::ranges`): analyze each op under the
/// declared operand ranges (`--ranges=lo:hi,...`; full width when
/// omitted), report the constant/dead/narrowing findings, and
/// cross-check every claim concretely against the executable circuit
/// (`soundness_check`, `--check` evaluation budget per op) — exiting
/// nonzero when any claim is unsound.
fn cmd_analyze(args: &cli::Args) -> Result<()> {
    use pudtune::pud::plan::{PudOp, WorkloadPlan};
    use pudtune::pud::ranges::{analyze_plan, soundness_check, OperandRange};
    use pudtune::pud::verify::DiagCode;

    let max_width = args.usize("max-width", 16).map_err(anyhow::Error::msg)?;
    let budget = args.usize("check", 4096).map_err(anyhow::Error::msg)?;
    let json = args.flag("json");
    let declared: Option<Vec<OperandRange>> = match args.str("ranges") {
        None => None,
        Some(spec) => Some(
            spec.split(',')
                .map(|s| OperandRange::parse(s.trim()).map_err(|e| anyhow!("--ranges: {e}")))
                .collect::<Result<Vec<_>>>()?,
        ),
    };
    let op_names = args.list("op");
    let ops: Vec<PudOp> = if op_names.is_empty() {
        PudOp::vocabulary(max_width)
    } else {
        op_names
            .iter()
            .map(|n| PudOp::parse_or_list(n).map_err(|e| anyhow!(e)))
            .collect::<Result<Vec<_>>>()?
    };

    let mut unsound = 0usize;
    let mut narrowable = 0usize;
    for op in ops {
        let label = op.label();
        let plan = WorkloadPlan::compile(op).map_err(|e| anyhow!("{label}: {e}"))?;
        let ranges: Vec<OperandRange> = match &declared {
            Some(r) => {
                if r.len() != plan.op.n_operands() {
                    return Err(anyhow!(
                        "--ranges: {} range(s) given but {label} takes {} operand(s)",
                        r.len(),
                        plan.op.n_operands()
                    ));
                }
                r.clone()
            }
            None => (0..plan.op.n_operands())
                .map(|_| OperandRange::full(plan.op.operand_width()))
                .collect(),
        };
        let report = analyze_plan(&plan, &ranges).map_err(|e| anyhow!("{label}: {e}"))?;
        let findings = soundness_check(&plan, &report, budget, 0xA7A);
        let span: Vec<String> = ranges.iter().map(|r| r.to_string()).collect();
        let span = span.join(",");
        if json {
            let fs: Vec<String> = findings.iter().map(|f| format!("{f:?}")).collect();
            println!(
                "{{\"target\":\"{label}\",\"analysis\":{},\"unsound\":[{}]}}",
                report.to_json(),
                fs.join(",")
            );
        } else if report.is_clean() {
            println!("{label} ({span}): clean, {} gates", report.gates);
        } else {
            println!(
                "{label} ({span}): {} finding(s), {} -> {} gates",
                report.diagnostics.len(),
                report.gates,
                report.narrowed_gates()
            );
            for d in &report.diagnostics {
                println!("  {d}");
            }
        }
        if !json {
            for f in &findings {
                println!("  UNSOUND: {f}");
            }
        }
        unsound += findings.len();
        if report.has(DiagCode::NarrowingOpportunity) {
            narrowable += 1;
        }
    }
    println!("narrowable: {narrowable}");
    println!("unsound: {unsound}");
    if unsound > 0 {
        return Err(anyhow!("range analysis is unsound on {unsound} claim(s)"));
    }
    Ok(())
}

fn cmd_fit_model(args: &cli::Args) -> Result<()> {
    let (cfg, sys, _) = load_configs(args)?;
    let target = args.f64("target", 0.466).map_err(anyhow::Error::msg)?;
    let fitted = sweep::fit_sigma_sa(&cfg, &sys, target, 0xF17);
    println!(
        "fitted sigma_sa = {:.4} (target baseline ECR {:.1}%)",
        fitted.sigma_sa,
        target * 100.0
    );
    println!(
        "closed-form check: baseline ECR estimate = {:.1}%",
        sweep::baseline_ecr_estimate(&fitted, 3, 3.0) * 100.0
    );
    println!("\n[device]\nsigma_sa = {:.5}", fitted.sigma_sa);
    Ok(())
}

fn cmd_trace(args: &cli::Args) -> Result<()> {
    let (cfg, sys, _) = load_configs(args)?;
    let fracs = args.fracs("fracs", [2, 1, 0]).map_err(anyhow::Error::msg)?;
    let m = match args.positional.first().map(|s| s.as_str()) {
        Some("maj3") => 3,
        _ => 5,
    };
    let mut sub = Subarray::with_geometry(&cfg, 64, 64, 1);
    let map = RowMap::standard(sub.rows);
    let _ = &mut sub;
    let mut p = BenderProgram::new();
    for i in 0..m {
        p.row_copy(map.data_base + i, map.simra_base + i);
    }
    for (i, &store) in map.calib_store.iter().enumerate() {
        p.row_copy(store, map.simra_base + m + i);
    }
    if m == 3 {
        p.row_copy(map.const0, map.simra_base + 6);
        p.row_copy(map.const1, map.simra_base + 7);
    }
    for (i, &n) in fracs.iter().enumerate() {
        for _ in 0..n {
            p.frac(map.simra_base + m + i);
        }
    }
    p.simra(map.simra_base);
    // Render through the scheduler for a power-honest trace.
    use pudtune::controller::command;
    use pudtune::controller::scheduler::Scheduler;
    let mut sched = Scheduler::new(sys.timing.clone());
    let close = sys.timing.t_ras + sys.timing.t_rp;
    for step in &p.steps {
        match step {
            pudtune::controller::bender::PudStep::RowCopy { src, dst } => {
                sched.issue(&command::row_copy_seq(*src, *dst), close);
            }
            pudtune::controller::bender::PudStep::Frac { row } => {
                sched.issue(&command::frac_seq(*row), sys.timing.t_rp);
            }
            pudtune::controller::bender::PudStep::Simra { base } => {
                sched.issue(&command::simra_seq(*base, base + 7), close);
            }
            _ => {}
        }
    }
    println!(
        "MAJ{m} command trace (T_{{{},{},{}}}):",
        fracs[0], fracs[1], fracs[2]
    );
    print!("{}", sched.trace.render());
    println!(
        "makespan: {:.1} ns, {} ACTs",
        sched.elapsed_ns(),
        sched.trace.act_count()
    );
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let rt = Runtime::open_default()?;
    println!("PJRT platform: {}", rt.platform());
    for name in rt.artifact_names() {
        let exe = rt.load(&name)?;
        println!(
            "  {name}: {} inputs, outputs {:?}, cols={:?}",
            exe.inputs.len(),
            exe.outputs,
            exe.meta_usize("cols")
        );
    }
    Ok(())
}

fn cmd_cross_check(args: &cli::Args) -> Result<()> {
    let (cfg, sys, _) = load_configs(args)?;
    let rt = Arc::new(Runtime::open_default()?);
    let (pjrt, native) = experiments::cross_check(&cfg, &rt, sys.cols)?;
    println!(
        "baseline MAJ5 ECR  pjrt={:.3}  native={:.3}  |diff|={:.3}",
        pjrt,
        native,
        (pjrt - native).abs()
    );
    anyhow::ensure!((pjrt - native).abs() < 0.05, "engines disagree");
    println!("cross-check OK");
    Ok(())
}
