//! Analytics: error-prone-column ratio, the Eq. 1 throughput model and
//! paper-style report rendering.

pub mod ecr;
pub mod report;
pub mod throughput;
