//! Error-prone column ratio (paper §IV-A).
//!
//! "We define ECR as the percentage of columns that output no errors
//! across all rows in a subarray" — i.e. a column is *error-prone* if
//! it produced at least one wrong MAJX result over the test battery
//! (8,192 random inputs in the paper).

/// Per-column error statistics of one measurement.
#[derive(Clone, Debug)]
pub struct EcrReport {
    /// Errors observed per column.
    pub error_counts: Vec<u32>,
    /// Random patterns tested per column.
    pub samples: u32,
}

impl EcrReport {
    pub fn from_error_counts(error_counts: Vec<u32>, samples: u32) -> Self {
        Self { error_counts, samples }
    }

    pub fn cols(&self) -> usize {
        self.error_counts.len()
    }

    /// Error-prone column ratio in [0, 1].
    pub fn ecr(&self) -> f64 {
        if self.error_counts.is_empty() {
            return 0.0;
        }
        self.error_prone() as f64 / self.cols() as f64
    }

    /// Number of columns with at least one error.
    pub fn error_prone(&self) -> usize {
        self.error_counts.iter().filter(|&&e| e > 0).count()
    }

    /// Number of error-free columns (the Eq. 1 numerator).
    pub fn error_free(&self) -> usize {
        self.cols() - self.error_prone()
    }

    /// Per-column error-free mask.
    pub fn error_free_mask(&self) -> Vec<bool> {
        self.error_counts.iter().map(|&e| e == 0).collect()
    }

    /// Columns error-free in *both* measurements (arithmetic circuits
    /// need every constituent MAJX to be reliable on a column).
    pub fn intersect(&self, other: &EcrReport) -> EcrReport {
        assert_eq!(self.cols(), other.cols());
        let error_counts = self
            .error_counts
            .iter()
            .zip(&other.error_counts)
            .map(|(&a, &b)| a + b)
            .collect();
        EcrReport { error_counts, samples: self.samples + other.samples }
    }

    /// Columns that are error-prone here but were error-free in a
    /// reference measurement — the "new error-prone columns" metric of
    /// Fig. 6.
    pub fn new_error_prone_vs(&self, reference: &EcrReport) -> usize {
        assert_eq!(self.cols(), reference.cols());
        self.error_counts
            .iter()
            .zip(&reference.error_counts)
            .filter(|(&now, &before)| now > 0 && before == 0)
            .count()
    }

    /// New-error ratio relative to all columns (Fig. 6 y-axis).
    pub fn new_ecr_vs(&self, reference: &EcrReport) -> f64 {
        self.new_error_prone_vs(reference) as f64 / self.cols() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios() {
        let r = EcrReport::from_error_counts(vec![0, 2, 0, 1], 100);
        assert_eq!(r.error_prone(), 2);
        assert_eq!(r.error_free(), 2);
        assert!((r.ecr() - 0.5).abs() < 1e-12);
        assert_eq!(r.error_free_mask(), vec![true, false, true, false]);
    }

    #[test]
    fn intersection_is_conservative() {
        let a = EcrReport::from_error_counts(vec![0, 1, 0, 0], 10);
        let b = EcrReport::from_error_counts(vec![0, 0, 3, 0], 10);
        let j = a.intersect(&b);
        assert_eq!(j.error_free(), 2);
        assert!(j.ecr() >= a.ecr().max(b.ecr()));
    }

    #[test]
    fn new_errors_vs_reference() {
        let before = EcrReport::from_error_counts(vec![0, 1, 0, 0], 10);
        let after = EcrReport::from_error_counts(vec![1, 1, 0, 2], 10);
        assert_eq!(after.new_error_prone_vs(&before), 2); // cols 0 and 3
        assert!((after.new_ecr_vs(&before) - 0.5).abs() < 1e-12);
    }
}
