//! The Eq. 1 throughput model.
//!
//! `Throughput = #error-free columns / latency of MAJX`, with the
//! latency of the 16-bank-parallel stream set by the rank ACT power
//! budget (paper §IV-A). Arithmetic throughput divides further by the
//! majority-operation cost of the circuit (MVDRAM full-adder
//! construction), with the op counts taken from the actual circuit
//! graphs in `pud::{adder, multiplier}`.

use crate::calib::lattice::FracConfig;
use crate::config::system::SystemConfig;
use crate::controller::power::ActPowerModel;
use crate::controller::timing::{majx_cost, MajxCost, PrimitiveTiming};
use crate::pud::graph::CircuitCost;

/// System-level throughput calculator.
#[derive(Clone, Debug)]
pub struct ThroughputModel {
    pub sys: SystemConfig,
    pub timing: PrimitiveTiming,
    pub power: ActPowerModel,
}

/// Throughput numbers for one configuration (one Table I row).
#[derive(Clone, Copy, Debug)]
pub struct ThroughputReport {
    /// Error-free columns in the whole system.
    pub error_free_columns: usize,
    /// Effective MAJ5 period per bank, ns.
    pub maj5_period_ns: f64,
    /// MAJ5 ops/s, system-wide (Table I "MAJ5").
    pub maj5_ops: f64,
    /// 8-bit additions/s (Table I "8-bit ADD").
    pub add8_ops: f64,
    /// 8-bit multiplications/s (Table I "8-bit MUL").
    pub mul8_ops: f64,
}

impl ThroughputModel {
    pub fn new(sys: &SystemConfig) -> Self {
        Self {
            sys: sys.clone(),
            timing: PrimitiveTiming::from_grade(&sys.timing),
            power: ActPowerModel::from_grade(&sys.timing),
        }
    }

    /// Cost of one MAJ-m with the given Frac configuration.
    pub fn majx(&self, m: usize, fc: &FracConfig) -> MajxCost {
        majx_cost(&self.timing, m, fc.total_fracs())
    }

    /// Effective per-bank period of an operation stream whose unit op
    /// costs `cost` (ACT-power bound across the configured banks).
    pub fn period_ns(&self, cost: &MajxCost) -> f64 {
        self.power
            .op_period_ns(cost.latency_ns, cost.acts, self.sys.banks)
    }

    /// Ops/s across the system (Eq. 1): every error-free column of
    /// every bank completes one op per effective period. The period
    /// already folds in the rank ACT-budget serialisation across the
    /// bank-parallel streams, so total = columns × EFC / period.
    pub fn ops_per_sec(&self, cost: &MajxCost, error_free_frac: f64) -> f64 {
        let columns = self.sys.total_columns() as f64 * error_free_frac;
        columns / (self.period_ns(cost) * 1e-9)
    }

    /// Full Table-I style report.
    ///
    /// `ecr_maj5` / `ecr_arith`: error-prone ratios for MAJ5 alone and
    /// for the arithmetic circuits (MAJ5 ∧ MAJ3 reliability);
    /// `add_cost`/`mul_cost` come from `pud::{adder, multiplier}`.
    pub fn report(
        &self,
        fc: &FracConfig,
        ecr_maj5: f64,
        ecr_arith: f64,
        add_cost: &CircuitCost,
        mul_cost: &CircuitCost,
    ) -> ThroughputReport {
        let maj5 = self.majx(5, fc);
        let maj3 = self.majx(3, fc);
        let efc5 = 1.0 - ecr_maj5;
        let efc_arith = 1.0 - ecr_arith;
        let add = self.circuit_cost_ns(add_cost, fc);
        let mul = self.circuit_cost_ns(mul_cost, fc);
        let _ = maj3;
        ThroughputReport {
            error_free_columns: (self.sys.total_columns() as f64 * efc5) as usize,
            maj5_period_ns: self.period_ns(&maj5),
            maj5_ops: self.ops_per_sec(&maj5, efc5),
            add8_ops: self.ops_per_sec(&add, efc_arith),
            mul8_ops: self.ops_per_sec(&mul, efc_arith),
        }
    }

    /// Effective workload throughput (Eq. 1 over a whole circuit):
    /// ops/s of a workload whose unit cost is `cost`, at an *observed*
    /// error-free column fraction — what `pudtune run`, the workload
    /// benches and [`crate::coordinator::service`] report for served
    /// [`crate::pud::plan::WorkloadPlan`]s (pass `plan.cost` and the
    /// plan's mask density).
    pub fn workload_ops(&self, cost: &CircuitCost, fc: &FracConfig, error_free_frac: f64) -> f64 {
        self.ops_per_sec(&self.circuit_cost_ns(cost, fc), error_free_frac)
    }

    /// Aggregate command cost of a majority circuit under `fc`.
    pub fn circuit_cost_ns(&self, c: &CircuitCost, fc: &FracConfig) -> MajxCost {
        let maj3 = self.majx(3, fc);
        let maj5 = self.majx(5, fc);
        // NOT: read out + write back inverted (column interface).
        let not_ns = self.timing.readout_ns + self.timing.write_ns;
        let not_acts = self.timing.readout_acts + self.timing.write_acts;
        MajxCost {
            latency_ns: c.maj3 as f64 * maj3.latency_ns
                + c.maj5 as f64 * maj5.latency_ns
                + c.not_ops as f64 * not_ns,
            acts: c.maj3 * maj3.acts + c.maj5 * maj5.acts + c.not_ops * not_acts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pud::{adder, multiplier};

    fn model() -> ThroughputModel {
        ThroughputModel::new(&SystemConfig::paper())
    }

    #[test]
    fn baseline_maj5_lands_near_paper() {
        // Table I baseline: ECR 46.6% -> 0.89 TOPS. The shape target:
        // same order of magnitude, 0.6-1.3 TOPS.
        let m = model();
        let fc = FracConfig::baseline(3);
        let cost = m.majx(5, &fc);
        let tops = m.ops_per_sec(&cost, 1.0 - 0.466) / 1e12;
        assert!((0.6..1.3).contains(&tops), "tops={tops}");
    }

    #[test]
    fn equal_frac_configs_have_equal_latency() {
        // B_{3,0,0} and T_{2,1,0} both apply 3 Fracs -> identical MAJ5
        // latency -> the throughput gain equals the EFC gain (1.81x).
        let m = model();
        let b = m.majx(5, &FracConfig::baseline(3));
        let t = m.majx(5, &FracConfig::pudtune([2, 1, 0]));
        assert_eq!(b.acts, t.acts);
        assert!((b.latency_ns - t.latency_ns).abs() < 1e-9);
        let gain = m.ops_per_sec(&t, 1.0 - 0.033) / m.ops_per_sec(&b, 1.0 - 0.466);
        assert!((1.7..1.95).contains(&gain), "gain={gain}");
    }

    #[test]
    fn arithmetic_ratios_match_paper_shape() {
        // Paper: MAJ5 0.89 TOPS vs ADD 50.2 GOPS (ratio ~17.7x) vs
        // MUL 5.8 GOPS (ratio ~153x).
        let m = model();
        let fc = FracConfig::baseline(3);
        let add = m.circuit_cost_ns(&adder::add8_cost(), &fc);
        let mul = m.circuit_cost_ns(&multiplier::mul8_cost(), &fc);
        let maj5 = m.majx(5, &fc);
        let r_add = add.acts as f64 / maj5.acts as f64;
        let r_mul = mul.acts as f64 / maj5.acts as f64;
        assert!((12.0..25.0).contains(&r_add), "r_add={r_add}");
        assert!((110.0..240.0).contains(&r_mul), "r_mul={r_mul}");
        // MUL:ADD cost ratio near the paper's 153/17.7 = 8.6x.
        assert!((6.0..14.0).contains(&(r_mul / r_add)), "{}", r_mul / r_add);
    }

    #[test]
    fn workload_ops_scales_with_the_error_free_fraction() {
        // Equal Frac budgets -> equal latency, so the effective uplift
        // of a served workload is exactly the mask-density ratio (how
        // Table I's 1.88x/1.89x add/mul gains arise).
        let m = model();
        let base = FracConfig::baseline(3);
        let tune = FracConfig::pudtune([2, 1, 0]);
        let add = adder::add8_cost();
        let full = m.workload_ops(&add, &tune, 1.0);
        let half = m.workload_ops(&add, &tune, 0.5);
        assert!((full / half - 2.0).abs() < 1e-9);
        assert_eq!(full, m.ops_per_sec(&m.circuit_cost_ns(&add, &tune), 1.0));
        let uplift = m.workload_ops(&add, &tune, 1.0 - 0.062)
            / m.workload_ops(&add, &base, 1.0 - 0.50);
        assert!((1.7..2.0).contains(&uplift), "uplift={uplift}");
    }

    #[test]
    fn fewer_fracs_run_faster() {
        let m = model();
        let t000 = m.majx(5, &FracConfig::pudtune([0, 0, 0]));
        let t222 = m.majx(5, &FracConfig::pudtune([2, 2, 2]));
        assert!(m.period_ns(&t000) < m.period_ns(&t222));
    }
}
