//! Paper-style report rendering (Table I rows, Fig. 5/6 series) plus
//! JSON export for downstream tooling.

use crate::analysis::throughput::ThroughputReport;
use crate::calib::lattice::FracConfig;
use crate::util::json::Json;
use crate::util::table::{fmt_ops, Table};
use std::collections::BTreeMap;

/// One Table-I style row.
#[derive(Clone, Debug)]
pub struct TableRow {
    pub label: String,
    pub ecr: f64,
    pub report: ThroughputReport,
}

/// Render rows in the paper's Table I format.
pub fn render_table1(rows: &[TableRow]) -> String {
    let mut t = Table::new(&["Method", "ECR", "MAJ5", "8-bit ADD", "8-bit MUL"]);
    for r in rows {
        t.row(&[
            r.label.clone(),
            format!("{:.1}%", r.ecr * 100.0),
            fmt_ops(r.report.maj5_ops),
            fmt_ops(r.report.add8_ops),
            fmt_ops(r.report.mul8_ops),
        ]);
    }
    let mut out = t.render();
    if rows.len() == 2 {
        let (b, p) = (&rows[0], &rows[1]);
        out.push_str(&format!(
            "\nimprovement: MAJ5 {:.2}x, ADD {:.2}x, MUL {:.2}x (paper: 1.81x / 1.88x / 1.89x)\n",
            p.report.maj5_ops / b.report.maj5_ops,
            p.report.add8_ops / b.report.add8_ops,
            p.report.mul8_ops / b.report.mul8_ops,
        ));
    }
    out
}

pub fn table1_json(rows: &[TableRow]) -> Json {
    let arr = rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("method".into(), Json::Str(r.label.clone()));
            m.insert("ecr".into(), Json::Num(r.ecr));
            m.insert("maj5_ops".into(), Json::Num(r.report.maj5_ops));
            m.insert("add8_ops".into(), Json::Num(r.report.add8_ops));
            m.insert("mul8_ops".into(), Json::Num(r.report.mul8_ops));
            Json::Obj(m)
        })
        .collect();
    Json::Arr(arr)
}

/// A Fig. 5 style sweep series entry.
pub fn render_sweep(points: &[(FracConfig, f64, f64)]) -> String {
    let mut t = Table::new(&["Config", "ECR", "MAJ5 throughput"]);
    for (fc, ecr, ops) in points {
        t.row(&[fc.label(), format!("{:.1}%", ecr * 100.0), fmt_ops(*ops)]);
    }
    t.render()
}

/// Fig. 6 style reliability series.
pub fn render_reliability(axis: &str, points: &[(f64, f64)]) -> String {
    let mut t = Table::new(&[axis, "new ECR"]);
    for (x, new_ecr) in points {
        t.row(&[format!("{x}"), format!("{:.3}%", new_ecr * 100.0)]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::throughput::ThroughputReport;

    fn row(label: &str, ecr: f64, ops: f64) -> TableRow {
        TableRow {
            label: label.into(),
            ecr,
            report: ThroughputReport {
                error_free_columns: 1000,
                maj5_period_ns: 2000.0,
                maj5_ops: ops,
                add8_ops: ops / 18.0,
                mul8_ops: ops / 150.0,
            },
        }
    }

    #[test]
    fn table1_includes_improvement_line() {
        let rows = vec![row("Baseline (B_{3,0,0})", 0.466, 0.9e12), row("PUDTune (T_{2,1,0})", 0.033, 1.6e12)];
        let s = render_table1(&rows);
        assert!(s.contains("ECR"));
        assert!(s.contains("46.6%"));
        assert!(s.contains("improvement: MAJ5 1.78x"));
    }

    #[test]
    fn json_export_shape() {
        let rows = vec![row("x", 0.1, 1e12)];
        let j = table1_json(&rows);
        assert_eq!(j.idx(0).get("method").as_str(), Some("x"));
        assert!(j.idx(0).get("maj5_ops").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn reliability_rendering() {
        let s = render_reliability("Temp (C)", &[(40.0, 0.0005), (100.0, 0.0013)]);
        assert!(s.contains("0.050%"));
        assert!(s.contains("0.130%"));
    }
}
