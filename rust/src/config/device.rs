//! Analog device model constants.
//!
//! The charge-sharing constants are pinned by the paper (§II-C): a 30 fF
//! cell against a 270 fF bitline gives a 0.55·V_DD single-cell read and
//! 0.529·V_DD for MAJ5(1,1,1,0,0) under 8-row SiMRA — both asserted in
//! the unit tests below. The *variation model* parameters (σ_SA, tail
//! mixture, per-op noise, Frac ratio) are fitted once against Table I's
//! baseline column by `pudtune fit-model` and then frozen for every
//! experiment (see EXPERIMENTS.md §Model-Fit).
//!
//! All voltages are in units of V_DD.

use crate::util::json::Json;

/// Physics + variation model of one DRAM device.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceConfig {
    /// Cell capacitance, fF (paper §II-C).
    pub cc_ff: f64,
    /// Bitline capacitance, fF (paper §II-C).
    pub cb_ff: f64,
    /// Bitline precharge voltage, V_DD units.
    pub v_pre: f64,
    /// Rows opened by one SiMRA (8 for both MAJ5 and MAJ3; see DESIGN §3).
    pub simra_rows: usize,
    /// Frac convergence ratio r: q <- 0.5 + (q-0.5)·r per Frac.
    pub frac_r: f64,
    /// Core std-dev of the per-column SA threshold offset.
    pub sigma_sa: f64,
    /// Heavy-tail mixture weight of the threshold offset distribution.
    pub tail_weight: f64,
    /// Tail component scale ratio (σ_tail = tail_ratio · σ_sa).
    pub tail_ratio: f64,
    /// Per-operation bitline/SA noise std-dev.
    pub sigma_noise: f64,
    /// SA threshold temperature coefficient, V_DD per °C (common mode).
    pub tempco: f64,
    /// Per-column tempco jitter std-dev, V_DD per °C.
    pub tempco_jitter: f64,
    /// Aging drift: per-column random-walk step std-dev per hour.
    pub drift_per_hour: f64,
    /// Temperature at which devices are calibrated, °C.
    pub t_cal: f64,
    /// Cell-charge retention time constant, hours: one `advance_time`
    /// interval of `dt` hours multiplies every cell's deviation from
    /// the neutral state by `exp(-dt / tau)` (see `dram::retention`).
    /// `INFINITY` (the default) disables charge decay entirely, which
    /// is the pre-retention model behaviour.
    pub tau_retention_hours: f64,
    /// Minimum retained swing fraction below which a full-swing row is
    /// no longer reliably restored by refresh: if one `advance_time`
    /// interval decays the swing factor below this threshold, the row's
    /// data degrades to the decayed analog levels instead of snapping
    /// back to the rails (`dram::subarray` module docs, "Retention").
    ///
    /// Note the semantics are **per `advance_time` call**: each call
    /// models one refresh-window check, so full-swing retention is
    /// deliberately *not* step-granularity invariant (unlike aging
    /// drift) — one `advance_time(T)` can degrade a row that many
    /// small steps summing to `T` would keep refreshed. Callers
    /// modelling a refresh interval should advance time in steps of
    /// that interval.
    pub retention_swing_min: f64,
    /// Fraction of columns carrying an injected PuDGhost-style fault
    /// (`dram::faults`). 0 (the default) disables fault injection
    /// entirely — the fault field is empty and SiMRA behaves
    /// byte-identically to the fault-free model.
    pub fault_col_rate: f64,
    /// Flip probability of pattern-dependent faults: applied whenever
    /// a faulty column's SiMRA latches a contested data pattern
    /// (summed charge near the majority boundary). 0 removes the
    /// class from the draw.
    pub fault_pattern_p: f64,
    /// Flip probability of aggressor/victim row-coupling faults:
    /// applied whenever the column's aggressor row position inside the
    /// activated group is strongly driven high. 0 removes the class.
    pub fault_coupling_p: f64,
    /// Flip probability of intermittent-column faults, applied during
    /// the active window of the column's duty cycle. 0 removes the
    /// class.
    pub fault_intermittent_p: f64,
    /// Duty-cycle period of intermittent columns, in SiMRA operations
    /// of the owning subarray (the active window is `period / 4`, at
    /// least 1). Must be ≥ 1.
    pub fault_intermittent_period: u64,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        Self {
            cc_ff: 30.0,
            cb_ff: 270.0,
            v_pre: 0.5,
            simra_rows: 8,
            frac_r: 0.65,
            // Fitted against Table I baseline (EXPERIMENTS.md §Model-Fit):
            // `pudtune fit-model` bisects sigma_sa until the B_{3,0,0}
            // ECR hits 46.6% (measured ~46.5% at these values); the
            // tail mixture then reproduces the PUDTune residual ECR
            // (~4% vs the paper's 3.3%) without further tuning.
            sigma_sa: 0.0284,
            tail_weight: 0.10,
            tail_ratio: 2.5,
            sigma_noise: 0.0020,
            // Reliability model (Fig. 6): SA sensing is differential,
            // so the common-mode temperature response largely cancels —
            // only a small residual coefficient plus per-column
            // mismatch jitter remains; aging is a slow random walk.
            tempco: 3.0e-6,
            tempco_jitter: 4.0e-6,
            drift_per_hour: 1.2e-5,
            t_cal: 45.0,
            tau_retention_hours: f64::INFINITY,
            retention_swing_min: 0.9,
            // Fault injection (dram::faults) is opt-in: a clean-lab
            // device by default, PuDGhost campaigns when enabled.
            fault_col_rate: 0.0,
            fault_pattern_p: 0.0,
            fault_coupling_p: 0.0,
            fault_intermittent_p: 0.0,
            fault_intermittent_period: 64,
        }
    }
}

impl DeviceConfig {
    /// Charge-sharing bitline voltage for the given total cell charge
    /// (cell-equivalents) across `rows` simultaneously opened rows.
    #[inline]
    pub fn bitline_voltage(&self, total_charge: f64, rows: usize) -> f64 {
        (self.cc_ff * total_charge + self.cb_ff * self.v_pre)
            / (rows as f64 * self.cc_ff + self.cb_ff)
    }

    /// Cell charge after `n` Frac operations starting from `initial`.
    #[inline]
    pub fn frac_charge(&self, initial: f64, n: u32) -> f64 {
        0.5 + (initial - 0.5) * self.frac_r.powi(n as i32)
    }

    /// The analog margin of a MAJX decision: half the voltage gap
    /// between the k = ceil(X/2) and k = ceil(X/2)-1 operand states
    /// (±0.0294·V_DD for 8-row SiMRA with ideal calibration charge).
    pub fn majority_margin(&self) -> f64 {
        let rows = self.simra_rows as f64;
        0.5 * self.cc_ff / (rows * self.cc_ff + self.cb_ff)
    }

    /// Validate the invariants the decay/drift paths rely on. The
    /// retention model treats only `tau_retention_hours = INFINITY`
    /// (off) and finite positive values as meaningful; zero, negative
    /// and NaN taus are configuration errors — `swing_factor` guards
    /// against them at runtime, but they should be rejected where the
    /// config enters the system (here, called by every parse path).
    pub fn validate(&self) -> Result<(), String> {
        if self.tau_retention_hours.is_nan() || self.tau_retention_hours <= 0.0 {
            return Err(format!(
                "tau_retention_hours must be a positive number of hours \
                 (or INFINITY to disable decay), got {}",
                self.tau_retention_hours
            ));
        }
        // `contains` is false for NaN, so NaN is rejected here too.
        if !(0.0..=1.0).contains(&self.retention_swing_min) {
            return Err(format!(
                "retention_swing_min must lie in [0, 1], got {}",
                self.retention_swing_min
            ));
        }
        if self.drift_per_hour.is_nan() || self.drift_per_hour < 0.0 {
            return Err(format!(
                "drift_per_hour must be non-negative, got {}",
                self.drift_per_hour
            ));
        }
        // `contains` is false for NaN, so these reject NaN too.
        for (name, v) in [
            ("fault_col_rate", self.fault_col_rate),
            ("fault_pattern_p", self.fault_pattern_p),
            ("fault_coupling_p", self.fault_coupling_p),
            ("fault_intermittent_p", self.fault_intermittent_p),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("{name} must lie in [0, 1], got {v}"));
            }
        }
        if self.fault_intermittent_period == 0 {
            return Err("fault_intermittent_period must be at least 1".into());
        }
        Ok(())
    }

    /// Load from `artifacts/physics.json` (emitted by the Python build
    /// step) so both sides provably share one model.
    pub fn from_physics_json(j: &Json) -> Result<Self, String> {
        let f = |k: &str| -> Result<f64, String> {
            j.get(k).as_f64().ok_or_else(|| format!("physics.json missing '{k}'"))
        };
        let mut cfg = Self::default();
        cfg.cc_ff = f("cc_ff")?;
        cfg.cb_ff = f("cb_ff")?;
        cfg.v_pre = f("v_pre")?;
        cfg.simra_rows = f("simra_rows")? as usize;
        cfg.frac_r = f("frac_r")?;
        cfg.sigma_sa = f("sigma_sa")?;
        cfg.tail_weight = f("tail_weight")?;
        cfg.tail_ratio = f("tail_ratio")?;
        cfg.sigma_noise = f("sigma_noise")?;
        // Retention keys are optional: physics.json files emitted
        // before the hybrid-storage model omit them, and the defaults
        // (no decay) reproduce the old behaviour exactly.
        if let Some(v) = j.get("tau_retention_hours").as_f64() {
            cfg.tau_retention_hours = v;
        }
        if let Some(v) = j.get("retention_swing_min").as_f64() {
            cfg.retention_swing_min = v;
        }
        // Fault-injection keys are likewise optional (default: no
        // faults); `validate` rejects out-of-range rates/probabilities
        // and a zero duty-cycle period at parse time.
        if let Some(v) = j.get("fault_col_rate").as_f64() {
            cfg.fault_col_rate = v;
        }
        if let Some(v) = j.get("fault_pattern_p").as_f64() {
            cfg.fault_pattern_p = v;
        }
        if let Some(v) = j.get("fault_coupling_p").as_f64() {
            cfg.fault_coupling_p = v;
        }
        if let Some(v) = j.get("fault_intermittent_p").as_f64() {
            cfg.fault_intermittent_p = v;
        }
        if !matches!(j.get("fault_intermittent_period"), Json::Null) {
            cfg.fault_intermittent_period = j
                .get("fault_intermittent_period")
                .as_exact_u64()
                .ok_or("fault_intermittent_period must be a non-negative integer")?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper §II-C: 30 fF cell / 270 fF bitline -> 0.55 V_DD read voltage.
    #[test]
    fn single_cell_read_voltage() {
        let c = DeviceConfig::default();
        let v = c.bitline_voltage(1.0, 1);
        assert!((v - 0.55).abs() < 1e-12, "{v}");
    }

    /// Paper §II-C: MAJ5(1,1,1,0,0) with neutral calibration (Q = 1.5)
    /// under 8-row SiMRA -> ~0.529 V_DD.
    #[test]
    fn maj5_boundary_voltage() {
        let c = DeviceConfig::default();
        let v = c.bitline_voltage(3.0 + 1.5, 8);
        assert!((v - 0.52941).abs() < 1e-4, "{v}");
        let v_lo = c.bitline_voltage(2.0 + 1.5, 8);
        assert!((v_lo - 0.47059).abs() < 1e-4, "{v_lo}");
    }

    /// The margin helper matches the explicit boundary voltages.
    #[test]
    fn margin_matches_boundaries() {
        let c = DeviceConfig::default();
        let hi = c.bitline_voltage(4.5, 8);
        let m = c.majority_margin();
        assert!((hi - 0.5 - m).abs() < 1e-12);
    }

    /// Frac converges toward neutral; 8 Fracs leave <5% deviation
    /// (FracDRAM: 6-10 Fracs reach the neutral state).
    #[test]
    fn frac_convergence() {
        let c = DeviceConfig::default();
        let mut q = 1.0;
        for _ in 0..8 {
            q = 0.5 + (q - 0.5) * c.frac_r;
        }
        assert!((q - 0.5).abs() < 0.05, "{q}");
        assert!((c.frac_charge(1.0, 8) - q).abs() < 1e-12);
        // Monotone approach from both sides.
        assert!(c.frac_charge(0.0, 1) < c.frac_charge(0.0, 0) + 1.0);
        assert!(c.frac_charge(0.0, 2) > c.frac_charge(0.0, 1));
        assert!(c.frac_charge(1.0, 2) < c.frac_charge(1.0, 1));
    }

    #[test]
    fn retention_defaults_disable_decay() {
        let d = DeviceConfig::default();
        assert!(d.tau_retention_hours.is_infinite());
        assert!((0.0..=1.0).contains(&d.retention_swing_min));
    }

    #[test]
    fn validate_rejects_degenerate_retention_taus() {
        let ok = DeviceConfig::default();
        assert!(ok.validate().is_ok(), "default config must validate");
        let finite = DeviceConfig { tau_retention_hours: 64.0, ..ok.clone() };
        assert!(finite.validate().is_ok());
        for bad_tau in [0.0, -24.0, f64::NAN] {
            let bad = DeviceConfig { tau_retention_hours: bad_tau, ..ok.clone() };
            let err = bad.validate().unwrap_err();
            assert!(err.contains("tau_retention_hours"), "{err}");
        }
        for bad_min in [-0.1, 1.5, f64::NAN] {
            let bad = DeviceConfig { retention_swing_min: bad_min, ..ok.clone() };
            assert!(bad.validate().unwrap_err().contains("retention_swing_min"));
        }
        let bad = DeviceConfig { drift_per_hour: f64::NAN, ..ok };
        assert!(bad.validate().unwrap_err().contains("drift_per_hour"));
    }

    #[test]
    fn physics_json_rejects_degenerate_retention_taus() {
        use crate::util::json;
        for bad in ["0.0", "-3.5"] {
            let src = format!(
                r#"{{"cc_ff":30.0,"cb_ff":270.0,"v_pre":0.5,"simra_rows":8,
                    "frac_r":0.65,"sigma_sa":0.0284,"tail_weight":0.1,"tail_ratio":2.5,
                    "sigma_noise":0.002,"tau_retention_hours":{bad}}}"#
            );
            let err = DeviceConfig::from_physics_json(&json::parse(&src).unwrap()).unwrap_err();
            assert!(err.contains("tau_retention_hours"), "{err}");
        }
    }

    #[test]
    fn physics_json_retention_keys_parse_when_present() {
        use crate::util::json;
        let src = r#"{"cc_ff":30.0,"cb_ff":270.0,"v_pre":0.5,"simra_rows":8,
            "frac_r":0.65,"sigma_sa":0.0284,"tail_weight":0.1,"tail_ratio":2.5,
            "sigma_noise":0.002,"tau_retention_hours":64.0,"retention_swing_min":0.8}"#;
        let cfg = DeviceConfig::from_physics_json(&json::parse(src).unwrap()).unwrap();
        assert_eq!(cfg.tau_retention_hours, 64.0);
        assert_eq!(cfg.retention_swing_min, 0.8);
    }

    #[test]
    fn fault_defaults_are_off_and_validate() {
        let d = DeviceConfig::default();
        assert_eq!(d.fault_col_rate, 0.0);
        assert_eq!(d.fault_pattern_p, 0.0);
        assert_eq!(d.fault_coupling_p, 0.0);
        assert_eq!(d.fault_intermittent_p, 0.0);
        assert!(d.fault_intermittent_period >= 1);
        d.validate().unwrap();
    }

    #[test]
    fn validate_rejects_degenerate_fault_knobs() {
        let ok = DeviceConfig::default();
        for bad_p in [-0.1, 1.5, f64::NAN] {
            let bad = DeviceConfig { fault_col_rate: bad_p, ..ok.clone() };
            assert!(bad.validate().unwrap_err().contains("fault_col_rate"));
            let bad = DeviceConfig { fault_pattern_p: bad_p, ..ok.clone() };
            assert!(bad.validate().unwrap_err().contains("fault_pattern_p"));
            let bad = DeviceConfig { fault_coupling_p: bad_p, ..ok.clone() };
            assert!(bad.validate().unwrap_err().contains("fault_coupling_p"));
            let bad = DeviceConfig { fault_intermittent_p: bad_p, ..ok.clone() };
            assert!(bad.validate().unwrap_err().contains("fault_intermittent_p"));
        }
        let bad = DeviceConfig { fault_intermittent_period: 0, ..ok };
        assert!(bad.validate().unwrap_err().contains("fault_intermittent_period"));
    }

    #[test]
    fn physics_json_fault_keys_parse_and_validate() {
        use crate::util::json;
        let base = r#""cc_ff":30.0,"cb_ff":270.0,"v_pre":0.5,"simra_rows":8,
            "frac_r":0.65,"sigma_sa":0.0284,"tail_weight":0.1,"tail_ratio":2.5,
            "sigma_noise":0.002"#;
        let src = format!(
            r#"{{{base},"fault_col_rate":0.05,"fault_pattern_p":1.0,
                "fault_intermittent_p":0.5,"fault_intermittent_period":32}}"#
        );
        let cfg = DeviceConfig::from_physics_json(&json::parse(&src).unwrap()).unwrap();
        assert_eq!(cfg.fault_col_rate, 0.05);
        assert_eq!(cfg.fault_pattern_p, 1.0);
        assert_eq!(cfg.fault_coupling_p, 0.0, "absent keys keep the off default");
        assert_eq!(cfg.fault_intermittent_p, 0.5);
        assert_eq!(cfg.fault_intermittent_period, 32);
        // Out-of-range probability and fractional/zero periods are
        // parse-time errors, not silently accepted configs.
        let bad = format!(r#"{{{base},"fault_col_rate":1.5}}"#);
        let err = DeviceConfig::from_physics_json(&json::parse(&bad).unwrap()).unwrap_err();
        assert!(err.contains("fault_col_rate"), "{err}");
        for bad_period in ["0", "2.5", "-4"] {
            let bad = format!(r#"{{{base},"fault_intermittent_period":{bad_period}}}"#);
            let err =
                DeviceConfig::from_physics_json(&json::parse(&bad).unwrap()).unwrap_err();
            assert!(err.contains("fault_intermittent_period"), "{err}");
        }
    }

    #[test]
    fn physics_json_roundtrip() {
        use crate::util::json;
        let d = DeviceConfig::default();
        let src = format!(
            r#"{{"cc_ff":{},"cb_ff":{},"v_pre":{},"simra_rows":{},"frac_r":{},
                "sigma_sa":{},"tail_weight":{},"tail_ratio":{},"sigma_noise":{}}}"#,
            d.cc_ff, d.cb_ff, d.v_pre, d.simra_rows, d.frac_r, d.sigma_sa,
            d.tail_weight, d.tail_ratio, d.sigma_noise
        );
        let cfg = DeviceConfig::from_physics_json(&json::parse(&src).unwrap()).unwrap();
        assert_eq!(cfg, DeviceConfig { ..cfg.clone() });
        assert!((cfg.sigma_sa - d.sigma_sa).abs() < 1e-12);
    }
}
