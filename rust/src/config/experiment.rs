//! Experiment parameterisation, defaulting to the paper's §IV values.

/// Parameters shared by the paper's experiments.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Master seed; every stochastic field derives from it.
    pub seed: u64,
    /// Calibration iterations (paper: 20).
    pub calib_iterations: u32,
    /// Random samples per calibration iteration (paper: 512).
    pub calib_samples: u32,
    /// Random inputs for ECR measurement (paper: 8,192 per bank).
    pub ecr_samples: u32,
    /// Number of banks measured (paper: every bank of 16 modules; we
    /// default to one subarray per bank of the configured system).
    pub banks: usize,
    /// Algorithm-1 bias threshold.
    pub bias_tau: f64,
    /// Temperatures for Fig. 6a, °C.
    pub temperatures: Vec<f64>,
    /// Time checkpoints for Fig. 6b, hours.
    pub time_checkpoints_h: Vec<f64>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            seed: 0x9d_2025,
            calib_iterations: 20,
            calib_samples: 512,
            ecr_samples: 8192,
            banks: 16,
            bias_tau: 0.02,
            temperatures: vec![40.0, 50.0, 60.0, 70.0, 80.0, 90.0, 100.0],
            time_checkpoints_h: (0..8).map(|d| d as f64 * 24.0).collect(),
        }
    }
}

impl ExperimentConfig {
    /// Reduced-size configuration for tests (fast, same code paths).
    pub fn quick() -> Self {
        Self {
            calib_iterations: 12,
            calib_samples: 256,
            ecr_samples: 2048,
            banks: 2,
            ..Self::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_defaults() {
        let e = ExperimentConfig::default();
        assert_eq!(e.calib_iterations, 20);
        assert_eq!(e.calib_samples, 512);
        assert_eq!(e.ecr_samples, 8192);
        assert_eq!(e.temperatures.first().copied(), Some(40.0));
        assert_eq!(e.temperatures.last().copied(), Some(100.0));
        // One week of checkpoints.
        assert_eq!(e.time_checkpoints_h.last().copied(), Some(168.0));
    }
}
