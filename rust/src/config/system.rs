//! System geometry and memory-interface grade.
//!
//! Matches the paper's evaluation platform (§IV-A): DDR4-2133, 4-channel
//! system, 16 bank-parallel PUD, subarrays of 512 rows × 65,536 columns
//! (the column count spans the whole rank: 8 chips × 8,192 bitlines).

/// DDR4 speed-grade timing parameters, in nanoseconds.
#[derive(Clone, Debug, PartialEq)]
pub struct Ddr4Timing {
    /// Clock period.
    pub t_ck: f64,
    /// ACT to PRE minimum (row active time).
    pub t_ras: f64,
    /// PRE to ACT (row precharge).
    pub t_rp: f64,
    /// ACT to internal read/write delay.
    pub t_rcd: f64,
    /// Four-activate window (rank-level ACT power constraint).
    pub t_faw: f64,
    /// ACT-to-ACT different bank (short).
    pub t_rrd_s: f64,
    /// ACT-to-ACT same bank group (long).
    pub t_rrd_l: f64,
    /// Refresh command interval.
    pub t_refi: f64,
    /// Refresh cycle time.
    pub t_rfc: f64,
}

impl Ddr4Timing {
    /// DDR4-2133P (the paper's modules).
    pub fn ddr4_2133() -> Self {
        Self {
            t_ck: 0.9375,
            t_ras: 33.0,
            t_rp: 13.5,
            t_rcd: 13.5,
            // x8 devices: tFAW = max(20 CK, 25 ns) at DDR4-2133.
            t_faw: 25.0,
            t_rrd_s: 3.7,
            t_rrd_l: 5.3,
            t_refi: 7800.0,
            t_rfc: 350.0,
        }
    }

    /// Round a duration up to a whole number of clocks (commands are
    /// issued on clock edges).
    pub fn to_clocks(&self, ns: f64) -> u64 {
        (ns / self.t_ck).ceil() as u64
    }
}

/// Geometry of the simulated system.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    /// Memory channels (paper: 4).
    pub channels: usize,
    /// Banks per channel usable in parallel for PUD (paper: 16).
    pub banks: usize,
    /// Subarrays simulated per bank (experiments measure one subarray
    /// per bank and scale; the paper calibrates per subarray).
    pub subarrays_per_bank: usize,
    /// Rows per subarray (paper: 256-1,024; we use 512).
    pub rows_per_subarray: usize,
    /// Columns per subarray across the rank (paper: 65,536).
    pub cols: usize,
    /// Timing grade.
    pub timing: Ddr4Timing,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            channels: 4,
            banks: 16,
            subarrays_per_bank: 1,
            rows_per_subarray: 512,
            cols: 16384, // single-core default; --full switches to 65,536
            timing: Ddr4Timing::ddr4_2133(),
        }
    }
}

impl SystemConfig {
    /// The paper's full-scale geometry (65,536 columns per subarray).
    pub fn paper() -> Self {
        Self { cols: 65536, ..Self::default() }
    }

    /// A small geometry for unit tests and doc examples.
    pub fn small() -> Self {
        Self { channels: 1, banks: 2, cols: 1024, ..Self::default() }
    }

    /// Total columns participating in bank-parallel PUD.
    pub fn total_columns(&self) -> usize {
        self.channels * self.banks * self.cols
    }

    /// Fraction of subarray capacity reserved for calibration rows
    /// (paper §III-D: 3 of 512 rows = 0.6%).
    pub fn calib_capacity_overhead(&self, calib_rows: usize) -> f64 {
        calib_rows as f64 / self.rows_per_subarray as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry() {
        let s = SystemConfig::paper();
        assert_eq!(s.total_columns(), 4 * 16 * 65536);
        // §III-D: 0.6% capacity overhead for 3 calibration rows.
        let ovh = s.calib_capacity_overhead(3);
        assert!((ovh - 0.00586).abs() < 1e-4, "{ovh}");
    }

    #[test]
    fn clock_rounding() {
        let t = Ddr4Timing::ddr4_2133();
        assert_eq!(t.to_clocks(0.9375), 1);
        assert_eq!(t.to_clocks(1.0), 2);
        assert_eq!(t.to_clocks(33.0), 36);
    }
}
