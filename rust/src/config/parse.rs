//! `key = value` config-file format (a TOML subset) for the CLI.
//!
//! Supports comments (`#`), sections (`[device]`, `[system]`,
//! `[experiment]`), numbers, booleans, strings and number lists
//! (`temps = [40, 60, 80]`). Section + key pairs map onto the config
//! structs; unknown keys are reported as errors so typos don't silently
//! fall back to defaults.

use std::collections::BTreeMap;

use super::device::DeviceConfig;
use super::experiment::ExperimentConfig;
use super::system::SystemConfig;

/// A parsed config file: section -> key -> raw value.
#[derive(Debug, Default, Clone)]
pub struct ConfigFile {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Num(f64),
    Bool(bool),
    Str(String),
    List(Vec<f64>),
}

impl Value {
    fn parse(raw: &str) -> Result<Value, String> {
        let raw = raw.trim();
        if raw == "true" {
            return Ok(Value::Bool(true));
        }
        if raw == "false" {
            return Ok(Value::Bool(false));
        }
        if let Some(inner) = raw.strip_prefix('[').and_then(|r| r.strip_suffix(']')) {
            let mut xs = Vec::new();
            for part in inner.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                xs.push(part.parse::<f64>().map_err(|_| format!("bad list item '{part}'"))?);
            }
            return Ok(Value::List(xs));
        }
        if let Some(inner) = raw.strip_prefix('"').and_then(|r| r.strip_suffix('"')) {
            return Ok(Value::Str(inner.to_string()));
        }
        raw.parse::<f64>().map(Value::Num).map_err(|_| format!("bad value '{raw}'"))
    }

    fn as_f64(&self) -> Result<f64, String> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => Err("expected a number".into()),
        }
    }
}

/// Parse the text of a config file.
pub fn parse(text: &str) -> Result<ConfigFile, String> {
    let mut cf = ConfigFile::default();
    let mut section = String::from("");
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            cf.sections.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected 'key = value'", lineno + 1))?;
        let value =
            Value::parse(v).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        cf.sections
            .entry(section.clone())
            .or_default()
            .insert(k.trim().to_string(), value);
    }
    Ok(cf)
}

/// Fully resolved configuration bundle.
#[derive(Debug, Clone, Default)]
pub struct Resolved {
    pub device: DeviceConfig,
    pub system: SystemConfig,
    pub experiment: ExperimentConfig,
}

/// Apply a config file over the defaults; unknown keys error out, and
/// the resolved device model is validated (degenerate retention/drift
/// parameters are configuration errors, not runtime surprises).
pub fn resolve(cf: &ConfigFile) -> Result<Resolved, String> {
    let mut r = Resolved::default();
    for (section, kvs) in &cf.sections {
        for (k, v) in kvs {
            apply(&mut r, section, k, v)
                .map_err(|e| format!("[{section}] {k}: {e}"))?;
        }
    }
    r.device.validate().map_err(|e| format!("[device] {e}"))?;
    Ok(r)
}

fn apply(r: &mut Resolved, section: &str, k: &str, v: &Value) -> Result<(), String> {
    match (section, k) {
        ("device", "cc_ff") => r.device.cc_ff = v.as_f64()?,
        ("device", "cb_ff") => r.device.cb_ff = v.as_f64()?,
        ("device", "frac_r") => r.device.frac_r = v.as_f64()?,
        ("device", "sigma_sa") => r.device.sigma_sa = v.as_f64()?,
        ("device", "tail_weight") => r.device.tail_weight = v.as_f64()?,
        ("device", "tail_ratio") => r.device.tail_ratio = v.as_f64()?,
        ("device", "sigma_noise") => r.device.sigma_noise = v.as_f64()?,
        ("device", "tempco") => r.device.tempco = v.as_f64()?,
        ("device", "tempco_jitter") => r.device.tempco_jitter = v.as_f64()?,
        ("device", "drift_per_hour") => r.device.drift_per_hour = v.as_f64()?,
        ("device", "t_cal") => r.device.t_cal = v.as_f64()?,
        ("device", "tau_retention_hours") => r.device.tau_retention_hours = v.as_f64()?,
        ("device", "retention_swing_min") => r.device.retention_swing_min = v.as_f64()?,
        ("system", "channels") => r.system.channels = v.as_f64()? as usize,
        ("system", "banks") => r.system.banks = v.as_f64()? as usize,
        ("system", "rows_per_subarray") => r.system.rows_per_subarray = v.as_f64()? as usize,
        ("system", "cols") => r.system.cols = v.as_f64()? as usize,
        ("experiment", "seed") => r.experiment.seed = v.as_f64()? as u64,
        ("experiment", "calib_iterations") => r.experiment.calib_iterations = v.as_f64()? as u32,
        ("experiment", "calib_samples") => r.experiment.calib_samples = v.as_f64()? as u32,
        ("experiment", "ecr_samples") => r.experiment.ecr_samples = v.as_f64()? as u32,
        ("experiment", "banks") => r.experiment.banks = v.as_f64()? as usize,
        ("experiment", "bias_tau") => r.experiment.bias_tau = v.as_f64()?,
        ("experiment", "temperatures") => {
            if let Value::List(xs) = v {
                r.experiment.temperatures = xs.clone();
            } else {
                return Err("expected a list".into());
            }
        }
        ("experiment", "time_checkpoints_h") => {
            if let Value::List(xs) = v {
                r.experiment.time_checkpoints_h = xs.clone();
            } else {
                return Err("expected a list".into());
            }
        }
        _ => return Err("unknown configuration key".into()),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_resolve() {
        let text = r#"
# paper-scale run
[device]
sigma_sa = 0.042

[system]
cols = 65536
channels = 4

[experiment]
calib_iterations = 20
temperatures = [40, 70, 100]
"#;
        let cf = parse(text).unwrap();
        let r = resolve(&cf).unwrap();
        assert_eq!(r.system.cols, 65536);
        assert!((r.device.sigma_sa - 0.042).abs() < 1e-12);
        assert_eq!(r.experiment.temperatures, vec![40.0, 70.0, 100.0]);
        // Untouched keys keep defaults.
        assert_eq!(r.system.banks, 16);
    }

    #[test]
    fn retention_keys_parse_and_validate() {
        let r = resolve(
            &parse("[device]\ntau_retention_hours = 64\nretention_swing_min = 0.8\n").unwrap(),
        )
        .unwrap();
        assert_eq!(r.device.tau_retention_hours, 64.0);
        assert_eq!(r.device.retention_swing_min, 0.8);
        // `inf` keeps decay off (the default).
        let r = resolve(&parse("[device]\ntau_retention_hours = inf\n").unwrap()).unwrap();
        assert!(r.device.tau_retention_hours.is_infinite());
        // Zero, negative and NaN taus are config errors.
        for bad in ["0", "-24", "nan"] {
            let text = format!("[device]\ntau_retention_hours = {bad}\n");
            let err = resolve(&parse(&text).unwrap()).unwrap_err();
            assert!(err.contains("tau_retention_hours"), "{bad}: {err}");
        }
        let err = resolve(&parse("[device]\nretention_swing_min = 1.5\n").unwrap()).unwrap_err();
        assert!(err.contains("retention_swing_min"), "{err}");
    }

    #[test]
    fn unknown_key_errors() {
        let cf = parse("[device]\nsigma_typo = 1\n").unwrap();
        let err = resolve(&cf).unwrap_err();
        assert!(err.contains("sigma_typo"));
    }

    #[test]
    fn bad_syntax_errors() {
        assert!(parse("[device]\nnonsense\n").is_err());
        assert!(parse("[device]\nx = [1, two]\n").is_err());
    }

    #[test]
    fn strings_and_bools() {
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
    }
}
