//! Configuration system.
//!
//! * [`device`] — the analog physics model of one DRAM device (the
//!   constants pinned by the paper plus the fitted variation model);
//! * [`system`] — system geometry: channels, banks, subarray shape and
//!   the DDR4 timing grade;
//! * [`experiment`] — per-experiment knobs (sample counts, iterations,
//!   temperatures, sweep grids), defaulting to the paper's §IV values;
//! * [`parse`] — a small `key = value` config-file format (TOML subset)
//!   so devices/experiments can be described in files and passed to the
//!   CLI with `--config`.

pub mod device;
pub mod experiment;
pub mod parse;
pub mod system;
