//! Per-primitive latency derived from the DDR4 speed grade.
//!
//! Latency of a primitive = its violated command prologue plus the
//! regular close-out (tRAS restore + tRP precharge) before the bank can
//! accept the next primitive. Values land near the ComputeDRAM /
//! FracDRAM measurements for DDR4-2133 (~50 ns RowCopy, ~20 ns Frac).

use crate::config::system::Ddr4Timing;
use crate::controller::command::{self, Command};

/// Latencies (ns) and ACT counts of every PUD primitive.
#[derive(Clone, Debug, PartialEq)]
pub struct PrimitiveTiming {
    pub row_copy_ns: f64,
    pub frac_ns: f64,
    pub simra_ns: f64,
    /// Result readout: ACT + RD burst + PRE.
    pub readout_ns: f64,
    /// Full-row write: ACT + WR burst + PRE.
    pub write_ns: f64,
    pub row_copy_acts: u32,
    pub frac_acts: u32,
    pub simra_acts: u32,
    pub readout_acts: u32,
    pub write_acts: u32,
    /// Refresh duty overhead factor (tRFC / tREFI), applied to
    /// sustained rates.
    pub refresh_overhead: f64,
}

impl PrimitiveTiming {
    pub fn from_grade(t: &Ddr4Timing) -> Self {
        let seq_ns = |seq: &[Command]| -> f64 {
            // Command-bus time of the violated prologue...
            let prologue: u32 = seq
                .iter()
                .map(|c| match c {
                    Command::Nop { cycles } => *cycles,
                    _ => 1,
                })
                .sum();
            prologue as f64 * t.t_ck
        };
        let close_ns = t.t_ras + t.t_rp; // restore + precharge
        let rc = seq_ns(&command::row_copy_seq(0, 1)) + close_ns;
        let fr = seq_ns(&command::frac_seq(0)) + t.t_rp;
        let sm = seq_ns(&command::simra_seq(0, 8)) + close_ns;
        let ro = t.t_rcd + 8.0 * t.t_ck + t.t_rp; // ACT..RD burst..PRE
        let wr = t.t_rcd + 8.0 * t.t_ck + t.t_rp;
        Self {
            row_copy_ns: rc,
            frac_ns: fr,
            simra_ns: sm,
            readout_ns: ro,
            write_ns: wr,
            row_copy_acts: command::act_count(&command::row_copy_seq(0, 1)),
            frac_acts: command::act_count(&command::frac_seq(0)),
            simra_acts: command::act_count(&command::simra_seq(0, 8)),
            readout_acts: 1,
            write_acts: 1,
            refresh_overhead: t.t_rfc / t.t_refi,
        }
    }
}

/// Command-sequence cost of one MAJX execution (paper §III-D flow).
///
/// Every 8-row SiMRA preloads its full group: m operand RowCopies plus
/// 3 calibration-row RowCopies plus (8 - m - 3) constant-row RowCopies
/// — 8 copies total for both MAJ5 and MAJ3 — then the configured Frac
/// applications, the SiMRA itself, and one result readout.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MajxCost {
    pub latency_ns: f64,
    pub acts: u32,
}

pub fn majx_cost(t: &PrimitiveTiming, m: usize, total_fracs: u32) -> MajxCost {
    assert!(m == 3 || m == 5, "MAJ{m} not supported under 8-row SiMRA");
    let copies = 8u32;
    let latency_ns = copies as f64 * t.row_copy_ns
        + total_fracs as f64 * t.frac_ns
        + t.simra_ns
        + t.readout_ns;
    let acts = copies * t.row_copy_acts
        + total_fracs * t.frac_acts
        + t.simra_acts
        + t.readout_acts;
    MajxCost { latency_ns, acts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::Ddr4Timing;

    #[test]
    fn primitive_latencies_are_plausible() {
        let t = PrimitiveTiming::from_grade(&Ddr4Timing::ddr4_2133());
        // ComputeDRAM-era measurements: RowCopy ~50 ns, Frac ~20 ns.
        assert!((45.0..60.0).contains(&t.row_copy_ns), "{}", t.row_copy_ns);
        assert!((15.0..25.0).contains(&t.frac_ns), "{}", t.frac_ns);
        assert!(t.simra_ns > t.frac_ns);
        assert!(t.refresh_overhead < 0.06);
    }

    #[test]
    fn maj5_cost_structure() {
        let t = PrimitiveTiming::from_grade(&Ddr4Timing::ddr4_2133());
        let c3 = majx_cost(&t, 5, 3);
        let c0 = majx_cost(&t, 5, 0);
        // Fewer Fracs -> strictly lower latency (paper §III-D: "varies
        // based on the total Frac operations used").
        assert!(c0.latency_ns < c3.latency_ns);
        assert_eq!(c3.acts - c0.acts, 3 * t.frac_acts);
        // 8 row copies (5 operands + 3 calib), 2 ACTs each, + SiMRA 2
        // + readout 1 + 3 fracs = 22 ACTs.
        assert_eq!(c3.acts, 8 * 2 + 2 + 1 + 3);
    }

    #[test]
    fn maj3_preloads_the_same_group() {
        // Both MAJ3 and MAJ5 fill the full 8-row SiMRA group, so the
        // per-op cost is identical at equal Frac counts.
        let t = PrimitiveTiming::from_grade(&Ddr4Timing::ddr4_2133());
        let maj3 = majx_cost(&t, 3, 3);
        let maj5 = majx_cost(&t, 5, 3);
        assert_eq!(maj3, maj5);
    }
}
