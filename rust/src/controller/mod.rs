//! Command-level DDR4 controller model (the DRAM Bender substitute).
//!
//! The paper drives its modules with DRAM Bender on an Alveo U200,
//! issuing ACT/PRE sequences that deliberately violate JEDEC timing to
//! trigger RowCopy, SiMRA and Frac. Throughput (Eq. 1) is then set by
//! the latency of those sequences under the rank's ACT power budget
//! (tFAW) with 16 banks operating in parallel (§IV-A).
//!
//! * [`command`] — the command vocabulary and violation sequences;
//! * [`timing`] — per-primitive latency derived from DDR4-2133 timings;
//! * [`power`] — the tFAW/ACT-budget model that caps bank parallelism;
//! * [`trace`] — recorded command streams (DRAM Bender program style);
//! * [`scheduler`] — turns primitive sequences into an issue schedule
//!   and a makespan;
//! * [`bender`] — a small program-builder API over all of the above,
//!   executing against the golden subarray model while accounting time.

pub mod bender;
pub mod command;
pub mod power;
pub mod scheduler;
pub mod timing;
pub mod trace;
