//! Issue scheduling: primitive sequences -> timed command stream.
//!
//! Tracks the bank-level timing state (violated prologues issue
//! back-to-back at their encoded offsets; primitive boundaries respect
//! the close-out latency) and the rank-level tFAW window so traces are
//! power-honest.
//!
//! ## Interleaving serving and recalibration
//!
//! When background recalibration shares a bank with a serving
//! workload, its primitive sequences are issued through
//! [`Scheduler::try_issue_background`]: a background sequence only
//! issues if it (including close-out) finishes before the caller's
//! deadline — typically the next serving batch's start cycle — and is
//! *deferred* otherwise, so recalibration soaks up idle gaps without
//! ever delaying the serving path. [`TraceClass`] accounting splits
//! the bank-busy cycles between the two workloads.

use crate::config::system::Ddr4Timing;
use crate::controller::command::Command;
use crate::controller::trace::CommandTrace;
use std::collections::VecDeque;

/// Which workload a primitive sequence belongs to when serving and
/// background recalibration share a bank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceClass {
    /// Foreground serving traffic.
    Serve,
    /// Background recalibration traffic.
    Recalib,
}

/// Scheduler for one bank within a rank.
#[derive(Clone, Debug)]
pub struct Scheduler {
    t: Ddr4Timing,
    /// Next cycle at which this bank may start a primitive.
    bank_ready: u64,
    /// Issue cycles of the last 4 ACTs on the rank (tFAW window).
    recent_acts: VecDeque<u64>,
    /// Busy cycles attributed to [serve, recalib] sequences.
    class_cycles: [u64; 2],
    /// Background sequences deferred past their deadline.
    deferred: u64,
    pub trace: CommandTrace,
}

impl Scheduler {
    pub fn new(t: Ddr4Timing) -> Self {
        Self {
            t,
            bank_ready: 0,
            recent_acts: VecDeque::new(),
            class_cycles: [0; 2],
            deferred: 0,
            trace: CommandTrace::default(),
        }
    }

    fn faw_clocks(&self) -> u64 {
        self.t.to_clocks(self.t.t_faw)
    }

    /// Issue a primitive's command sequence starting no earlier than the
    /// bank-ready cycle; `close_ns` is the recovery before the next
    /// primitive (tRAS+tRP for full restores, tRP for Frac). Untagged
    /// sequences count as serving traffic.
    pub fn issue(&mut self, seq: &[Command], close_ns: f64) -> u64 {
        self.issue_classed(seq, close_ns, TraceClass::Serve)
    }

    /// [`Self::issue`] with explicit workload attribution.
    pub fn issue_classed(&mut self, seq: &[Command], close_ns: f64, class: TraceClass) -> u64 {
        let start = self.bank_ready;
        let faw = self.faw_clocks();
        let mut cycle = self.bank_ready;
        for cmd in seq {
            cycle = step_command(cmd, cycle, &mut self.recent_acts, faw, Some(&mut self.trace));
        }
        self.bank_ready = cycle + self.t.to_clocks(close_ns);
        self.class_cycles[class as usize] += self.bank_ready - start;
        self.bank_ready
    }

    /// End cycle (including close-out) a sequence *would* reach if
    /// issued now, without mutating any state — the admission test for
    /// background work. Walks the exact same [`step_command`] logic as
    /// [`Self::issue_classed`] over a scratch ACT window.
    pub fn sequence_end(&self, seq: &[Command], close_ns: f64) -> u64 {
        let faw = self.faw_clocks();
        let mut cycle = self.bank_ready;
        let mut acts: VecDeque<u64> = self.recent_acts.clone();
        for cmd in seq {
            cycle = step_command(cmd, cycle, &mut acts, faw, None);
        }
        cycle + self.t.to_clocks(close_ns)
    }

    /// Issue a background (recalibration) sequence only if it finishes
    /// — close-out included — by `deadline_cycle`; defers it (returns
    /// `None`, counts [`Self::deferred_background`]) otherwise, so
    /// background work can never push the next serving sequence past
    /// its slot.
    pub fn try_issue_background(
        &mut self,
        seq: &[Command],
        close_ns: f64,
        deadline_cycle: u64,
    ) -> Option<u64> {
        if self.sequence_end(seq, close_ns) > deadline_cycle {
            self.deferred += 1;
            return None;
        }
        Some(self.issue_classed(seq, close_ns, TraceClass::Recalib))
    }

    /// Bank-busy cycles attributed to one workload class.
    pub fn class_cycles(&self, class: TraceClass) -> u64 {
        self.class_cycles[class as usize]
    }

    /// Background sequences deferred past their deadline so far.
    pub fn deferred_background(&self) -> u64 {
        self.deferred
    }

    /// Makespan in nanoseconds.
    pub fn elapsed_ns(&self) -> f64 {
        self.bank_ready as f64 * self.t.t_ck
    }
}

/// Advance one command against a bank timing state — the single source
/// of truth shared by the real issue walk ([`Scheduler::issue_classed`])
/// and the admission dry-run ([`Scheduler::sequence_end`]), so the two
/// can never drift apart. Records into `trace` only when given one.
fn step_command(
    cmd: &Command,
    cycle: u64,
    acts: &mut VecDeque<u64>,
    faw_clocks: u64,
    trace: Option<&mut CommandTrace>,
) -> u64 {
    match cmd {
        Command::Nop { cycles } => cycle + *cycles as u64,
        Command::Act { .. } => {
            let mut at = cycle;
            if acts.len() >= 4 {
                let oldest = acts[acts.len() - 4];
                at = at.max(oldest + faw_clocks);
            }
            if let Some(trace) = trace {
                trace.push(at, *cmd);
            }
            acts.push_back(at);
            if acts.len() > 8 {
                acts.pop_front();
            }
            at + 1
        }
        _ => {
            if let Some(trace) = trace {
                trace.push(cycle, *cmd);
            }
            cycle + 1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::command;

    #[test]
    fn sequences_advance_bank_ready() {
        let mut s = Scheduler::new(Ddr4Timing::ddr4_2133());
        let end1 = s.issue(&command::frac_seq(3), 13.5);
        let end2 = s.issue(&command::frac_seq(3), 13.5);
        assert!(end2 > end1);
        assert_eq!(s.trace.act_count(), 2);
    }

    #[test]
    fn tfaw_throttles_dense_acts() {
        let t = Ddr4Timing::ddr4_2133();
        let mut s = Scheduler::new(t.clone());
        // Issue 8 bare ACTs with no close-out: the 5th+ must wait for
        // the tFAW window.
        for _ in 0..8 {
            s.issue(&[Command::Act { row: 0 }], 0.0);
        }
        let acts: Vec<u64> = s
            .trace
            .entries
            .iter()
            .map(|(c, _)| *c)
            .collect();
        let faw = t.to_clocks(t.t_faw);
        assert!(acts[4] >= acts[0] + faw, "acts={acts:?}");
        assert!(acts[7] >= acts[3] + faw);
    }

    #[test]
    fn background_respects_the_serving_deadline() {
        let t = Ddr4Timing::ddr4_2133();
        let mut s = Scheduler::new(t.clone());
        let close = t.t_ras + t.t_rp;
        // One serving primitive, then a gap before the next serving
        // slot: the admission test decides per background sequence.
        let end = s.issue(&command::frac_seq(3), t.t_rp);
        // Deadline with no slack at all: the RowCopy defers.
        assert_eq!(s.try_issue_background(&command::row_copy_seq(8, 9), close, end), None);
        assert_eq!(s.deferred_background(), 1);
        let ready_before = s.trace.len();
        // A generous deadline admits it.
        let fits = s.sequence_end(&command::row_copy_seq(8, 9), close);
        let issued = s.try_issue_background(&command::row_copy_seq(8, 9), close, fits);
        assert_eq!(issued, Some(fits));
        assert!(s.trace.len() > ready_before);
        // Accounting: both classes saw busy cycles, and they add up to
        // the whole makespan (the bank never idles in this trace).
        let total = s.class_cycles(TraceClass::Serve) + s.class_cycles(TraceClass::Recalib);
        assert!(s.class_cycles(TraceClass::Serve) > 0);
        assert!(s.class_cycles(TraceClass::Recalib) > 0);
        assert_eq!(total, issued.unwrap());
    }

    #[test]
    fn dry_run_matches_real_issue() {
        let t = Ddr4Timing::ddr4_2133();
        let mut s = Scheduler::new(t.clone());
        for _ in 0..5 {
            s.issue(&[Command::Act { row: 0 }], 0.0);
        }
        let seq = command::row_copy_seq(1, 2);
        let predicted = s.sequence_end(&seq, t.t_rp);
        let actual = s.issue_classed(&seq, t.t_rp, TraceClass::Recalib);
        assert_eq!(predicted, actual);
    }

    #[test]
    fn rowcopy_trace_shape() {
        let mut s = Scheduler::new(Ddr4Timing::ddr4_2133());
        s.issue(&command::row_copy_seq(5, 9), 46.5);
        let txt = s.trace.render();
        assert!(txt.contains("row=5"));
        assert!(txt.contains("row=9"));
        assert!(txt.contains("(violated)"));
    }
}
