//! Issue scheduling: primitive sequences -> timed command stream.
//!
//! Tracks the bank-level timing state (violated prologues issue
//! back-to-back at their encoded offsets; primitive boundaries respect
//! the close-out latency) and the rank-level tFAW window so traces are
//! power-honest.

use crate::config::system::Ddr4Timing;
use crate::controller::command::Command;
use crate::controller::trace::CommandTrace;
use std::collections::VecDeque;

/// Scheduler for one bank within a rank.
#[derive(Clone, Debug)]
pub struct Scheduler {
    t: Ddr4Timing,
    /// Next cycle at which this bank may start a primitive.
    bank_ready: u64,
    /// Issue cycles of the last 4 ACTs on the rank (tFAW window).
    recent_acts: VecDeque<u64>,
    pub trace: CommandTrace,
}

impl Scheduler {
    pub fn new(t: Ddr4Timing) -> Self {
        Self { t, bank_ready: 0, recent_acts: VecDeque::new(), trace: CommandTrace::default() }
    }

    fn faw_clocks(&self) -> u64 {
        self.t.to_clocks(self.t.t_faw)
    }

    /// Earliest cycle >= `at` satisfying the tFAW constraint for an ACT.
    fn next_act_slot(&self, at: u64) -> u64 {
        if self.recent_acts.len() < 4 {
            return at;
        }
        let oldest = self.recent_acts[self.recent_acts.len() - 4];
        at.max(oldest + self.faw_clocks())
    }

    /// Issue a primitive's command sequence starting no earlier than the
    /// bank-ready cycle; `close_ns` is the recovery before the next
    /// primitive (tRAS+tRP for full restores, tRP for Frac).
    pub fn issue(&mut self, seq: &[Command], close_ns: f64) -> u64 {
        let mut cycle = self.bank_ready;
        for cmd in seq {
            match cmd {
                Command::Nop { cycles } => {
                    cycle += *cycles as u64;
                }
                Command::Act { .. } => {
                    cycle = self.next_act_slot(cycle);
                    self.trace.push(cycle, *cmd);
                    self.recent_acts.push_back(cycle);
                    if self.recent_acts.len() > 8 {
                        self.recent_acts.pop_front();
                    }
                    cycle += 1;
                }
                _ => {
                    self.trace.push(cycle, *cmd);
                    cycle += 1;
                }
            }
        }
        self.bank_ready = cycle + self.t.to_clocks(close_ns);
        self.bank_ready
    }

    /// Makespan in nanoseconds.
    pub fn elapsed_ns(&self) -> f64 {
        self.bank_ready as f64 * self.t.t_ck
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::command;

    #[test]
    fn sequences_advance_bank_ready() {
        let mut s = Scheduler::new(Ddr4Timing::ddr4_2133());
        let end1 = s.issue(&command::frac_seq(3), 13.5);
        let end2 = s.issue(&command::frac_seq(3), 13.5);
        assert!(end2 > end1);
        assert_eq!(s.trace.act_count(), 2);
    }

    #[test]
    fn tfaw_throttles_dense_acts() {
        let t = Ddr4Timing::ddr4_2133();
        let mut s = Scheduler::new(t.clone());
        // Issue 8 bare ACTs with no close-out: the 5th+ must wait for
        // the tFAW window.
        for _ in 0..8 {
            s.issue(&[Command::Act { row: 0 }], 0.0);
        }
        let acts: Vec<u64> = s
            .trace
            .entries
            .iter()
            .map(|(c, _)| *c)
            .collect();
        let faw = t.to_clocks(t.t_faw);
        assert!(acts[4] >= acts[0] + faw, "acts={acts:?}");
        assert!(acts[7] >= acts[3] + faw);
    }

    #[test]
    fn rowcopy_trace_shape() {
        let mut s = Scheduler::new(Ddr4Timing::ddr4_2133());
        s.issue(&command::row_copy_seq(5, 9), 46.5);
        let txt = s.trace.render();
        assert!(txt.contains("row=5"));
        assert!(txt.contains("row=9"));
        assert!(txt.contains("(violated)"));
    }
}
