//! Recorded command traces (DRAM Bender program style).
//!
//! Every command is stamped with its issue cycle; traces can be rendered
//! as text for inspection (`pudtune trace`) and are consumed by the
//! scheduler tests to assert timing-violation structure.

use crate::controller::command::Command;
use std::fmt::Write as _;

/// A timed command stream for one bank.
#[derive(Clone, Debug, Default)]
pub struct CommandTrace {
    /// (issue cycle, command)
    pub entries: Vec<(u64, Command)>,
}

impl CommandTrace {
    pub fn push(&mut self, cycle: u64, cmd: Command) {
        debug_assert!(
            self.entries.last().map(|(c, _)| *c <= cycle).unwrap_or(true),
            "commands must be issued in time order"
        );
        self.entries.push((cycle, cmd));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Last issue cycle (makespan in cycles).
    pub fn makespan(&self) -> u64 {
        self.entries.last().map(|(c, _)| *c).unwrap_or(0)
    }

    pub fn act_count(&self) -> usize {
        self.entries
            .iter()
            .filter(|(_, c)| matches!(c, Command::Act { .. }))
            .count()
    }

    /// Render as DRAM-Bender-style program text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (cycle, cmd) in &self.entries {
            let line = match cmd {
                Command::Act { row } => format!("ACT   row={row}"),
                Command::Pre { violated: true } => "PRE   (violated)".to_string(),
                Command::Pre { violated: false } => "PRE".to_string(),
                Command::Rd => "RD".to_string(),
                Command::Wr => "WR".to_string(),
                Command::Nop { cycles } => format!("NOP x{cycles}"),
            };
            let _ = writeln!(out, "{cycle:>8}: {line}");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_accumulates_in_order() {
        let mut t = CommandTrace::default();
        t.push(0, Command::Act { row: 1 });
        t.push(2, Command::Pre { violated: true });
        t.push(4, Command::Act { row: 2 });
        assert_eq!(t.len(), 3);
        assert_eq!(t.makespan(), 4);
        assert_eq!(t.act_count(), 2);
        let s = t.render();
        assert!(s.contains("ACT   row=1"));
        assert!(s.contains("(violated)"));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "time order")]
    fn out_of_order_push_asserts() {
        let mut t = CommandTrace::default();
        t.push(5, Command::Rd);
        t.push(1, Command::Wr);
    }
}
