//! ACT power budget (tFAW) — the constraint that sets PUD throughput.
//!
//! Every PUD primitive is a burst of ACTs, and a rank only sustains
//! 4 ACTs per tFAW window. With 16 banks running PUD in parallel the
//! command stream is ACT-bound long before any single bank's sequence
//! latency matters (paper §IV-A: "latency is derived from the 16
//! bank-parallel PUD under ACT power constraints").

use crate::config::system::Ddr4Timing;

/// Rank-level ACT budget model.
#[derive(Clone, Copy, Debug)]
pub struct ActPowerModel {
    /// Sustained ACT rate per rank, ACTs/ns.
    pub act_rate: f64,
    /// Refresh duty overhead factor (fraction of time lost to REF).
    pub refresh_overhead: f64,
}

impl ActPowerModel {
    pub fn from_grade(t: &Ddr4Timing) -> Self {
        Self { act_rate: 4.0 / t.t_faw, refresh_overhead: t.t_rfc / t.t_refi }
    }

    /// Effective per-bank operation period (ns) when `banks` banks each
    /// stream operations of `acts_per_op` ACTs and `seq_latency_ns`
    /// sequence latency: the maximum of the command-sequence bound and
    /// the rank ACT-budget bound, inflated by the refresh duty cycle.
    pub fn op_period_ns(&self, seq_latency_ns: f64, acts_per_op: u32, banks: usize) -> f64 {
        let act_bound = acts_per_op as f64 * banks as f64 / self.act_rate;
        let bound = act_bound.max(seq_latency_ns);
        bound / (1.0 - self.refresh_overhead)
    }

    /// Is the configuration ACT-bound (true for the paper's 16 banks)?
    pub fn is_act_bound(&self, seq_latency_ns: f64, acts_per_op: u32, banks: usize) -> bool {
        acts_per_op as f64 * banks as f64 / self.act_rate > seq_latency_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::Ddr4Timing;
    use crate::controller::timing::{majx_cost, PrimitiveTiming};

    #[test]
    fn sixteen_banks_are_act_bound() {
        let grade = Ddr4Timing::ddr4_2133();
        let pm = ActPowerModel::from_grade(&grade);
        let pt = PrimitiveTiming::from_grade(&grade);
        let c = majx_cost(&pt, 5, 3);
        assert!(pm.is_act_bound(c.latency_ns, c.acts, 16));
        // ...but a single bank is sequence-bound.
        assert!(!pm.is_act_bound(c.latency_ns, c.acts, 1));
    }

    #[test]
    fn op_period_scales_with_banks_when_act_bound() {
        let grade = Ddr4Timing::ddr4_2133();
        let pm = ActPowerModel::from_grade(&grade);
        let p16 = pm.op_period_ns(500.0, 22, 16);
        let p8 = pm.op_period_ns(500.0, 22, 8);
        assert!((p16 / p8 - 2.0).abs() < 0.01);
    }

    #[test]
    fn refresh_inflates_period() {
        let grade = Ddr4Timing::ddr4_2133();
        let pm = ActPowerModel::from_grade(&grade);
        let p = pm.op_period_ns(1000.0, 1, 1);
        assert!(p > 1000.0 && p < 1100.0);
    }
}
