//! DDR4 command vocabulary and the timing-violation sequences that
//! implement the PUD primitives (paper Fig. 2b; ComputeDRAM/FracDRAM).

/// A DDR4 command as issued on the command bus.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Command {
    /// Activate a row.
    Act { row: usize },
    /// Precharge the bank. `violated` marks a deliberately-early PRE.
    Pre { violated: bool },
    /// Column read (used by result readout).
    Rd,
    /// Column write (used to load operand/calibration data).
    Wr,
    /// Idle cycles (explicit NOPs between violated commands).
    Nop { cycles: u32 },
}

/// A PUD primitive expanded to its command sequence.
///
/// The cycle offsets of the violated sequences follow ComputeDRAM-style
/// `ACT - (T1 idle) - PRE - (T2 idle) - ACT` encodings:
/// * **RowCopy**: ACT(src), PRE after T1=1 cycles (too early to restore
///   fully), ACT(dst) after T2=2 cycles — the bitline still carries the
///   sensed source value and drives it into `dst`; then a regular
///   tRAS/tRP close.
/// * **Frac**: ACT(row), PRE after ~5 cycles — the restore is cut short
///   mid-swing, leaving a fractional charge; then tRP.
/// * **SiMRA**: ACT(addr A), violated PRE, ACT(addr B) — the decoder
///   glitch leaves multiple wordlines raised; charge shares; a full
///   tRAS restore writes the majority back into all opened rows.
pub fn row_copy_seq(src: usize, dst: usize) -> Vec<Command> {
    vec![
        Command::Act { row: src },
        Command::Nop { cycles: 1 },
        Command::Pre { violated: true },
        Command::Nop { cycles: 2 },
        Command::Act { row: dst },
    ]
}

pub fn frac_seq(row: usize) -> Vec<Command> {
    vec![
        Command::Act { row },
        Command::Nop { cycles: 5 },
        Command::Pre { violated: true },
    ]
}

pub fn simra_seq(base_row: usize, glitch_row: usize) -> Vec<Command> {
    vec![
        Command::Act { row: base_row },
        Command::Nop { cycles: 1 },
        Command::Pre { violated: true },
        Command::Nop { cycles: 1 },
        Command::Act { row: glitch_row },
    ]
}

/// Count the ACTs in a sequence (the unit the power model cares about).
pub fn act_count(seq: &[Command]) -> u32 {
    seq.iter()
        .filter(|c| matches!(c, Command::Act { .. }))
        .count() as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_have_expected_act_counts() {
        assert_eq!(act_count(&row_copy_seq(1, 2)), 2);
        assert_eq!(act_count(&frac_seq(1)), 1);
        assert_eq!(act_count(&simra_seq(0, 8)), 2);
    }

    #[test]
    fn violated_pre_is_marked() {
        let seq = row_copy_seq(1, 2);
        assert!(seq
            .iter()
            .any(|c| matches!(c, Command::Pre { violated: true })));
    }
}
