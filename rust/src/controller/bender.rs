//! Program-builder API over the command model + golden subarray
//! (the in-simulator equivalent of a DRAM Bender host program).
//!
//! A [`BenderProgram`] is a list of PUD primitives; `run` executes them
//! against the analog subarray model while the scheduler accounts a
//! power-honest command trace, so functional results and timing come
//! from one pass — exactly what the FPGA host does on real hardware.

use crate::config::system::Ddr4Timing;
use crate::controller::command;
use crate::controller::scheduler::Scheduler;
use crate::dram::subarray::Subarray;

/// One high-level PUD step.
#[derive(Clone, Debug, PartialEq)]
pub enum PudStep {
    /// Load full-swing data into a row via the column interface.
    WriteRow { row: usize, bits: Vec<u8> },
    /// Fill a row with a constant bit.
    FillRow { row: usize, bit: u8 },
    RowCopy { src: usize, dst: usize },
    Frac { row: usize },
    /// 8-row SiMRA over the aligned group starting at `base`.
    Simra { base: usize },
    /// Read a row out through the column interface.
    ReadRow { row: usize },
}

/// A recorded program plus its execution artifacts.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    /// Output of every `ReadRow` / `Simra`, in program order.
    pub reads: Vec<Vec<u8>>,
    pub elapsed_ns: f64,
    pub act_count: usize,
}

/// Builder/executor for PUD programs.
#[derive(Clone, Debug, Default)]
pub struct BenderProgram {
    pub steps: Vec<PudStep>,
}

impl BenderProgram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write_row(&mut self, row: usize, bits: Vec<u8>) -> &mut Self {
        self.steps.push(PudStep::WriteRow { row, bits });
        self
    }

    pub fn fill_row(&mut self, row: usize, bit: u8) -> &mut Self {
        self.steps.push(PudStep::FillRow { row, bit });
        self
    }

    pub fn row_copy(&mut self, src: usize, dst: usize) -> &mut Self {
        self.steps.push(PudStep::RowCopy { src, dst });
        self
    }

    pub fn frac(&mut self, row: usize) -> &mut Self {
        self.steps.push(PudStep::Frac { row });
        self
    }

    pub fn simra(&mut self, base: usize) -> &mut Self {
        self.steps.push(PudStep::Simra { base });
        self
    }

    pub fn read_row(&mut self, row: usize) -> &mut Self {
        self.steps.push(PudStep::ReadRow { row });
        self
    }

    /// Execute against a subarray, returning functional results and the
    /// power-honest timing of the command stream.
    pub fn run(&self, sub: &mut Subarray, grade: &Ddr4Timing) -> RunResult {
        let mut sched = Scheduler::new(grade.clone());
        let close_full = grade.t_ras + grade.t_rp;
        let close_pre = grade.t_rp;
        let io_seq = [
            command::Command::Act { row: 0 },
            command::Command::Nop { cycles: 8 },
            command::Command::Pre { violated: false },
        ];
        let mut out = RunResult::default();
        for step in &self.steps {
            match step {
                PudStep::WriteRow { row, bits } => {
                    sub.write_row(*row, bits);
                    sched.issue(&io_seq, close_pre);
                }
                PudStep::FillRow { row, bit } => {
                    sub.fill_row(*row, *bit);
                    sched.issue(&io_seq, close_pre);
                }
                PudStep::RowCopy { src, dst } => {
                    sub.row_copy(*src, *dst);
                    sched.issue(&command::row_copy_seq(*src, *dst), close_full);
                }
                PudStep::Frac { row } => {
                    sub.frac(*row);
                    sched.issue(&command::frac_seq(*row), close_pre);
                }
                PudStep::Simra { base } => {
                    let rows: Vec<usize> = (*base..*base + 8).collect();
                    let bits = sub.simra(&rows);
                    out.reads.push(bits);
                    sched.issue(&command::simra_seq(*base, *base + 7), close_full);
                }
                PudStep::ReadRow { row } => {
                    out.reads.push(sub.read_row(*row));
                    sched.issue(&io_seq, close_pre);
                }
            }
        }
        out.elapsed_ns = sched.elapsed_ns();
        out.act_count = sched.trace.act_count();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::device::DeviceConfig;

    fn quiet_subarray() -> Subarray {
        let mut cfg = DeviceConfig::default();
        cfg.sigma_sa = 1e-6;
        cfg.tail_weight = 0.0;
        cfg.sigma_noise = 1e-6;
        Subarray::with_geometry(&cfg, 64, 32, 3)
    }

    #[test]
    fn maj5_program_end_to_end() {
        // Fig. 1a flow as a Bender program on ideal columns.
        let mut sub = quiet_subarray();
        let grade = Ddr4Timing::ddr4_2133();
        let ones = vec![1u8; 32];
        let zeros = vec![0u8; 32];
        let mut p = BenderProgram::new();
        // Operands 1,1,1,0,0 then neutral rows: Frac'd row, const 0, 1.
        p.write_row(0, ones.clone())
            .write_row(1, ones.clone())
            .write_row(2, ones)
            .write_row(3, zeros.clone())
            .write_row(4, zeros)
            .fill_row(5, 1)
            .frac(5)
            .frac(5)
            .frac(5)
            .frac(5)
            .frac(5)
            .frac(5)
            .fill_row(6, 0)
            .fill_row(7, 1)
            .simra(0);
        let r = p.run(&mut sub, &grade);
        assert_eq!(r.reads.len(), 1);
        assert!(r.reads[0].iter().all(|&b| b == 1));
        assert!(r.elapsed_ns > 0.0);
        assert!(r.act_count >= 8);
    }

    #[test]
    fn timing_scales_with_fracs() {
        let grade = Ddr4Timing::ddr4_2133();
        let mk = |fracs: usize| {
            let mut sub = quiet_subarray();
            let mut p = BenderProgram::new();
            p.fill_row(5, 1);
            for _ in 0..fracs {
                p.frac(5);
            }
            p.run(&mut sub, &grade).elapsed_ns
        };
        assert!(mk(6) > mk(2));
    }
}
