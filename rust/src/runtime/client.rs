//! The PJRT runtime: loads `artifacts/*.hlo.txt`, compiles them on the
//! CPU PJRT client and caches the executables.
//!
//! HLO **text** is the interchange format (see `python/compile/aot.py`
//! and /opt/xla-example/README.md): the text parser reassigns
//! instruction ids, avoiding the 64-bit-id protos that xla_extension
//! 0.5.1 rejects.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::runtime::executable::{ArgSpec, Executable};
use crate::util::json::{self, Json};

/// Artifact loader + executable cache over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Json,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Open the artifacts directory (built by `make artifacts`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = json::parse(&text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Locate the artifacts directory next to the current exe / cwd.
    pub fn open_default() -> Result<Self> {
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(cand).join("manifest.json").exists() {
                return Self::open(cand);
            }
        }
        Err(anyhow!("artifacts/manifest.json not found — run `make artifacts`"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of all artifacts in the manifest.
    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest
            .get("artifacts")
            .as_obj()
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    /// The shared physics constants the artifacts were built against.
    pub fn physics_json(&self) -> Result<Json> {
        let text = std::fs::read_to_string(self.dir.join("physics.json"))?;
        json::parse(&text).map_err(|e| anyhow!("physics.json: {e}"))
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let entry = self.manifest.get("artifacts").get(name);
        let file = entry
            .get("file")
            .as_str()
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let inputs = entry
            .get("inputs")
            .as_arr()
            .ok_or_else(|| anyhow!("artifact '{name}': bad inputs"))?
            .iter()
            .map(|i| ArgSpec {
                name: i.get("name").as_str().unwrap_or("?").to_string(),
                shape: i
                    .get("shape")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default(),
                dtype: i.get("dtype").as_str().unwrap_or("?").to_string(),
            })
            .collect();
        let outputs = entry
            .get("outputs")
            .as_arr()
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default();
        let executable = std::sync::Arc::new(Executable {
            name: name.to_string(),
            exe,
            inputs,
            outputs,
            meta: entry.get("meta").clone(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), executable.clone());
        Ok(executable)
    }
}
