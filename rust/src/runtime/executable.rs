//! A compiled artifact with its manifest signature.

use anyhow::{Context, Result};
use xla::{Literal, PjRtLoadedExecutable};

use crate::util::json::Json;

/// Input signature entry from `artifacts/manifest.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// A loaded + compiled AOT artifact.
pub struct Executable {
    pub name: String,
    pub exe: PjRtLoadedExecutable,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<String>,
    /// Free-form metadata from the manifest (cols, samples, m, ...).
    pub meta: Json,
}

impl Executable {
    /// Execute with positional literals; returns the untupled outputs.
    /// (All graphs are lowered with `return_tuple=True`.)
    pub fn run(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        anyhow::ensure!(
            args.len() == self.inputs.len(),
            "{}: expected {} args, got {}",
            self.name,
            self.inputs.len(),
            args.len()
        );
        let result = self
            .exe
            .execute::<Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let parts = lit.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.name,
            self.outputs.len(),
            parts.len()
        );
        Ok(parts)
    }

    /// Integer metadata accessor (cols, samples, chunks, m, ...).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).as_usize()
    }
}
