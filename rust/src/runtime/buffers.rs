//! Literal construction/extraction helpers for the PJRT boundary.
//!
//! The AOT graphs take f32/i32 arrays and rank-0 scalars; these helpers
//! keep the (host Vec) <-> (xla::Literal) conversions in one place so
//! the hot path can reuse buffers and the signatures stay greppable.

use anyhow::Result;
use xla::Literal;

/// f32 vector literal of shape `[len]`.
pub fn f32_vec(data: &[f32]) -> Literal {
    Literal::vec1(data)
}

/// f32 literal reshaped to `dims`.
pub fn f32_array(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(Literal::vec1(data).reshape(dims)?)
}

/// i32 vector literal.
pub fn i32_vec(data: &[i32]) -> Literal {
    Literal::vec1(data)
}

/// Rank-0 scalars.
pub fn f32_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn u32_scalar(v: u32) -> Literal {
    Literal::scalar(v)
}

/// Extract a f32 vector from a literal.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract an i32 vector from a literal.
pub fn to_i32_vec(lit: &Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let l = f32_vec(&[1.0, 2.5, -3.0]);
        assert_eq!(to_f32_vec(&l).unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn reshape_checks_size() {
        assert!(f32_array(&[1.0, 2.0], &[3]).is_err());
        let l = f32_array(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
    }

    #[test]
    fn scalars_have_rank0() {
        let s = f32_scalar(7.5);
        assert_eq!(s.element_count(), 1);
        let u = u32_scalar(42);
        assert_eq!(u.element_count(), 1);
    }
}
