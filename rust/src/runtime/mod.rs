//! PJRT runtime: load + execute the AOT artifacts from the request path.
//!
//! `python/compile/aot.py` lowers the L2 JAX graphs (which embed the L1
//! Pallas kernels) to HLO text once at build time; this module loads
//! them into the `xla` crate's PJRT CPU client and executes them with
//! concrete inputs. Python never runs here.

pub mod buffers;
pub mod client;
pub mod executable;

pub use client::Runtime;
pub use executable::{ArgSpec, Executable};
