//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps `xla_extension` (PJRT CPU client + HLO parsing);
//! its dependency closure is not available in the offline vendor set, so
//! this stub provides the exact API surface `pudtune` uses:
//!
//! * [`Literal`] is **fully functional host-side** (typed buffers,
//!   shapes, tuples) — the buffer-conversion layer and its tests work
//!   unchanged;
//! * the PJRT client/executable types compile but report
//!   "backend unavailable" at runtime, so `Runtime::open_default()`
//!   fails cleanly and every engine falls back to the native path.
//!
//! Swap this path dependency for the real `xla` crate to execute the
//! AOT artifacts; no `pudtune` source changes are required.

use std::fmt;
use std::path::Path;

/// Stub error type (mirrors the real crate's string-ish errors).
#[derive(Clone, Debug)]
pub struct Error(String);

impl Error {
    fn backend_unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: PJRT backend unavailable (offline `xla` stub; build against \
             xla_extension to enable)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element storage of a [`Literal`] (public only because the
/// [`NativeType`] trait mentions it; not part of the mirrored API).
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

/// Host types storable in a [`Literal`].
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn slice(d: &Data) -> Option<&[Self]>;
}

macro_rules! native {
    ($t:ty, $variant:ident) => {
        impl NativeType for $t {
            fn wrap(v: Vec<Self>) -> Data {
                Data::$variant(v)
            }
            fn slice(d: &Data) -> Option<&[Self]> {
                match d {
                    Data::$variant(v) => Some(v),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(i32, I32);
native!(u32, U32);

/// A host-side typed array (rank-0 scalar, vector, reshaped array, or
/// tuple of literals).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { data: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Rank-0 scalar literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: Vec::new() }
    }

    /// Tuple literal (what executables return).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        let n = parts.len() as i64;
        Literal { data: Data::Tuple(parts), dims: vec![n] }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Same buffer under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: {} elements into shape {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy the elements out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::slice(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(v) => Ok(v.clone()),
            _ => Err(Error("to_tuple: literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (stub: never constructible from text offline).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        Err(Error::backend_unavailable(&format!(
            "parsing {}",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client (stub: construction always fails).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::backend_unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::backend_unavailable("compile"))
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// Compiled executable (stub: never constructible, `execute` errors).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::backend_unavailable("execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert_eq!(l.element_count(), 3);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(l.to_vec::<i32>().is_err());
        let r = l.reshape(&[3, 1]).unwrap();
        assert_eq!(r.element_count(), 3);
        assert!(l.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn scalars_and_tuples() {
        let s = Literal::scalar(7u32);
        assert_eq!(s.element_count(), 1);
        let t = Literal::tuple(vec![s.clone(), Literal::scalar(1.5f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<u32>().unwrap(), vec![7]);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn backend_is_cleanly_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
